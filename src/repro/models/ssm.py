"""Mamba2 (state-space duality / SSD) blocks.

Implements the chunked SSD algorithm (Dao & Gu 2024): within a chunk the
recurrence is materialized as an attention-like masked matmul (MXU-friendly);
across chunks a small recurrent state (H, hd, N) is carried by a scan.  The
intra-chunk compute is the Pallas-kernel hot spot (repro.kernels.ssd_scan);
this module holds the reference path and the block plumbing (projections,
depthwise causal conv, gating, decode-state updates).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Env, dense_init
from .layers import rms_norm

Params = Dict[str, Any]


def ssm_dims(d_model: int, expand: int, head_dim: int, n_state: int,
             conv_width: int) -> Dict[str, int]:
    d_inner = expand * d_model
    nheads = d_inner // head_dim
    d_conv = d_inner + 2 * n_state          # x, B, C go through the conv
    return dict(d_inner=d_inner, nheads=nheads, d_conv=d_conv,
                conv_width=conv_width, n_state=n_state, head_dim=head_dim)


def init_ssm(key, d_model: int, *, expand: int, head_dim: int, n_state: int,
             conv_width: int) -> Params:
    dims = ssm_dims(d_model, expand, head_dim, n_state, conv_width)
    k_in, k_out, k_conv, k_dt = jax.random.split(key, 4)
    d_in = dims["d_inner"]
    H = dims["nheads"]
    return {
        "in_proj": dense_init(k_in, (d_model, 2 * d_in + 2 * n_state + H)),
        "conv_w": dense_init(k_conv, (conv_width, dims["d_conv"]), in_axis=0),
        "conv_b": jnp.zeros((dims["d_conv"],)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k_dt, (H,)) *
                    (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)))),
        "norm": jnp.zeros((d_in,)),
        "out_proj": dense_init(k_out, (d_in, d_model)),
    }


# ---------------------------------------------------------------------------
# Chunked SSD scan (reference; kernels/ssd_scan provides the Pallas version
# of the per-chunk compute).
# ---------------------------------------------------------------------------

def ssd_scan(env: Env, x: jax.Array, dt: jax.Array, A: jax.Array,
             B: jax.Array, C: jax.Array, chunk: int,
             init_state: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """SSD over a sequence.

    x: (Bt, S, H, hd)   dt: (Bt, S, H)   A: (H,) negative
    B, C: (Bt, S, N)    (single SSM group, shared across heads)
    Returns (y: (Bt, S, H, hd), final_state: (Bt, H, hd, N)).
    """
    if env.use_pallas:
        from ..kernels.ssd_scan.ops import ssd_scan as ssd_kernel
        return ssd_kernel(x, dt, A, B, C, chunk=chunk, init_state=init_state,
                          interpret=env.interpret)
    from ..kernels.ssd_scan.ref import ssd_reference
    return ssd_reference(x, dt, A, B, C, chunk=chunk, init_state=init_state)


def _depthwise_causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                           state: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, jax.Array]:
    """Causal depthwise conv over (B, S, C) with kernel (W, C).

    ``state``: (B, W-1, C) history for streaming; returns (y, new_state).
    """
    Bt, S, Cch = x.shape
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((Bt, W - 1, Cch), dtype=x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)   # (B, S+W-1, C)
    # sum_w x[s + w] * k[w]  (causal: window ending at s)
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + xp[:, i:i + S, :] * w[i].astype(x.dtype)
    y = y + b.astype(x.dtype)
    new_state = xp[:, S:, :] if W > 1 else state
    return y, new_state


def ssm_block(env: Env, p: Params, x: jax.Array, cfg, *,
              cache: Optional[Tuple[jax.Array, jax.Array]] = None
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """One Mamba2 block (no outer norm/residual).

    cache = (ssm_state (B,H,hd,N), conv_state (B,W-1,Cconv)) for decoding;
    None for train/prefill (returns the fresh cache so prefill can serve).
    """
    dims = ssm_dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim,
                    cfg.ssm_state, cfg.ssm_conv_width)
    d_in, H, hd, N = (dims["d_inner"], dims["nheads"], dims["head_dim"],
                      dims["n_state"])
    Bt, S, _ = x.shape
    proj = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    z, xin, Bmat, Cmat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bmat, Cmat], axis=-1)
    conv_state = cache[1] if cache is not None else None
    conv_out, new_conv_state = _depthwise_causal_conv(
        conv_in, p["conv_w"], p["conv_b"], conv_state)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xin, Bmat, Cmat = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    xh = xin.reshape(Bt, S, H, hd)
    if env.tp_axis:
        xh = env.shard(xh, env.batch_spec_entry(), None, env.tp_axis, None)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,S,H)

    if cache is None or S > 1:
        init_state = cache[0] if cache is not None else None
        y, final_state = ssd_scan(env, xh, dt, A, Bmat, Cmat,
                                  chunk=cfg.ssm_chunk, init_state=init_state)
    else:
        # single-token decode: state' = exp(dt*A)*state + dt*B (x)
        state = cache[0]                                        # (B,H,hd,N)
        dt1 = dt[:, 0]                                          # (B,H)
        dA = jnp.exp(dt1 * A[None, :])                          # (B,H)
        xB = jnp.einsum("bhp,bn->bhpn", xh[:, 0].astype(jnp.float32),
                        Bmat[:, 0].astype(jnp.float32))
        final_state = dA[:, :, None, None] * state + dt1[:, :, None, None] * xB
        y = jnp.einsum("bhpn,bn->bhp", final_state,
                       Cmat[:, 0].astype(jnp.float32))
        y = y[:, None].astype(x.dtype)                          # (B,1,H,hd)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bt, S, d_in)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(x.dtype))
    new_cache = (final_state, new_conv_state)
    return out, new_cache
