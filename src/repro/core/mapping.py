"""Resource mapping (paper §7): acquisition (§7.1), DSM, RSM, SAM.

Thread-to-slot mapping operates on:

* :class:`VM` — a host with ``p_j`` homogeneous slots (one core + memory
  quantum each).  On the TPU adaptation a "VM" is an ICI-connected host and a
  "slot" is one chip.
* :class:`Thread` — one data-parallel executor ``r_i^k`` of task ``t_i``.
* :class:`Mapping` — the function ``M : R -> S`` plus residual-capacity
  bookkeeping, so predictors/simulators can inspect per-slot co-location.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import (Dict, Iterable, List, Mapping as TMapping, Optional,
                    Sequence, Tuple, Union)

import numpy as np

from .allocation import Allocation, TaskAllocation
from .dag import Dataflow
from .perfmodel import ModelLibrary


class InsufficientResourcesError(RuntimeError):
    """Raised when a resource-aware mapper cannot place a thread (RSM line 16,
    SAM lines 10/19).  The scheduler reacts by acquiring one more slot and
    retrying (§8.4)."""

    def __init__(self, task: str, message: str = ""):
        super().__init__(message or f"insufficient resources for task {task!r}")
        self.task = task


@dataclasses.dataclass(frozen=True)
class Thread:
    task: str
    index: int

    def __repr__(self) -> str:
        return f"{self.task}#{self.index}"


@dataclasses.dataclass(frozen=True)
class SlotId:
    vm: int
    slot: int

    def __repr__(self) -> str:
        return f"s{self.vm}.{self.slot}"


#: Azure D-series pricing per slot-hour (paper §7.1: price is proportional
#: to slots — $0.098/slot/h across D1..D4).
PRICE_PER_SLOT_HOUR = 0.098


@dataclasses.dataclass(frozen=True)
class VmClass:
    """A typed VM offering (§7.1 generalized): ``slots`` homogeneous slots
    whose threads each serve ``speed``× the profiled §6 service rate, priced
    at ``cost_per_hour`` dollars (default: the paper's slot-proportional
    D-series price) with ``mem_per_slot`` memory quanta per slot."""

    name: str
    slots: int
    speed: float = 1.0
    cost_per_hour: Optional[float] = None
    mem_per_slot: float = 1.0

    def __post_init__(self) -> None:
        if self.slots <= 0:
            raise ValueError(f"VmClass {self.name!r}: slots must be positive")
        if not (math.isfinite(self.speed) and self.speed > 0):
            raise ValueError(f"VmClass {self.name!r}: speed must be positive "
                             "and finite")
        if self.cost_per_hour is None:
            object.__setattr__(self, "cost_per_hour",
                               self.slots * PRICE_PER_SLOT_HOUR)
        if not (math.isfinite(self.cost_per_hour)
                and self.cost_per_hour >= 0):
            raise ValueError(f"VmClass {self.name!r}: cost_per_hour must be "
                             ">= 0 and finite")
        if not (math.isfinite(self.mem_per_slot) and self.mem_per_slot > 0):
            raise ValueError(f"VmClass {self.name!r}: mem_per_slot must be "
                             "positive and finite")


def vm_classes_from_sizes(sizes: Sequence[int], *, speed: float = 1.0,
                          price_per_slot_hour: float = PRICE_PER_SLOT_HOUR,
                          mem_per_slot: float = 1.0,
                          prefix: str = "d") -> Tuple[VmClass, ...]:
    """Unit-speed, slot-proportionally-priced classes for integer sizes —
    the homogeneous baseline every heterogeneous path must reproduce
    bit-identically."""
    return tuple(
        VmClass(f"{prefix}{s}", int(s), speed=speed,
                cost_per_hour=int(s) * price_per_slot_hour,
                mem_per_slot=mem_per_slot)
        for s in sorted({int(s) for s in sizes}, reverse=True))


#: Named class families used by the repo's planners: the paper's Azure
#: D-series (D3=4/D2=2/D1=1 slots), the serving planner's TPU hosts, and
#: the data-pipeline hosts (8-core machines down to singles).
VM_CLASS_FAMILIES: Dict[str, Tuple[VmClass, ...]] = {
    "azure-d": vm_classes_from_sizes((4, 2, 1)),
    "tpu-host": vm_classes_from_sizes((4, 2, 1), prefix="host"),
    "pipeline-host": vm_classes_from_sizes((8, 4, 2, 1), prefix="host"),
}


def vm_class_family(name: str) -> Tuple[VmClass, ...]:
    """A registered class family by name (``ValueError`` on unknown)."""
    try:
        return VM_CLASS_FAMILIES[name]
    except KeyError:
        raise ValueError(
            f"unknown VM class family {name!r}; registered: "
            f"{sorted(VM_CLASS_FAMILIES)}") from None


#: A ``vm_sizes`` argument anywhere in the planning stack: plain int slot
#: counts (the §7.1 baseline), :class:`VmClass` objects, or a registered
#: family name.
VmSizesArg = Union[str, Sequence[int], Sequence[VmClass]]


def resolve_vm_classes(vm_sizes: VmSizesArg) -> Tuple[VmClass, ...]:
    """Normalize a ``vm_sizes`` argument into :class:`VmClass` objects.
    Plain ints become anonymous unit-speed classes at the default price."""
    if isinstance(vm_sizes, str):
        return vm_class_family(vm_sizes)
    out: List[VmClass] = []
    seen = set()
    for s in vm_sizes:
        c = s if isinstance(s, VmClass) else VmClass(f"d{int(s)}", int(s))
        if c.name in seen:
            continue
        seen.add(c.name)
        out.append(c)
    if not out:
        raise ValueError("vm_sizes must name at least one class/size")
    return tuple(out)


def vm_sizes_speed(vm_sizes: VmSizesArg) -> float:
    """Common slot speed of a ``vm_sizes`` spec (1.0 for plain int sizes).
    Mixed speeds raise: one acquisition pools one speed — mixed-speed
    fleets plan per class (the ``min_cost`` objective)."""
    if not isinstance(vm_sizes, str) \
            and not any(isinstance(s, VmClass) for s in vm_sizes):
        return 1.0
    speeds = {c.speed for c in resolve_vm_classes(vm_sizes)}
    if len(speeds) > 1:
        raise ValueError(f"mixed slot speeds {sorted(speeds)} in one pool; "
                         "plan per class instead")
    return speeds.pop()


@dataclasses.dataclass
class VM:
    id: int
    num_slots: int
    rack: int = 0
    #: heterogeneity metadata — defaults reproduce the homogeneous unit-slot
    #: model, so ``VM(id, slots, rack)`` construction and equality are
    #: unchanged for every pre-existing call site
    speed: float = 1.0
    vm_class: str = ""
    cost_per_hour: Optional[float] = None
    mem_per_slot: float = 1.0

    @property
    def price_per_hour(self) -> float:
        if self.cost_per_hour is not None:
            return self.cost_per_hour
        return self.num_slots * PRICE_PER_SLOT_HOUR

    def slot_ids(self) -> List[SlotId]:
        return [SlotId(self.id, l) for l in range(self.num_slots)]


def pool_cost_per_hour(vms: Sequence[VM]) -> float:
    """Total $/hour of a VM pool (§7.1 pricing; class costs when tagged)."""
    return float(sum(vm.price_per_hour for vm in vms))


def pool_speed(vms: Sequence[VM], *, default: float = 1.0) -> float:
    """The pool's common slot speed (``default`` for an empty pool); a
    mixed-speed pool raises — allocation semantics are per-speed."""
    speeds = {vm.speed for vm in vms}
    if not speeds:
        return default
    if len(speeds) > 1:
        raise ValueError(f"mixed-speed VM pool {sorted(speeds)}")
    return speeds.pop()


def unit_vm_like(vm_id: int, pool: Sequence[VM]) -> VM:
    """A fresh 1-slot VM matching the pool's speed/memory shape — the §8.4
    +1-slot retry on a heterogeneous pool must not change its class
    semantics.  An empty pool gets the plain unit VM."""
    if not pool:
        return VM(vm_id, 1)
    ref = pool[0]
    return VM(vm_id, 1, speed=ref.speed, mem_per_slot=ref.mem_per_slot)


def nw_dist(ref: Optional[VM], cand: VM) -> float:
    """R-Storm network latency multiplier: 0 same VM, 0.5 same rack, 1.0
    otherwise (§7.3)."""
    if ref is None or ref.id == cand.id:
        return 0.0
    if ref.rack == cand.rack:
        return 0.5
    return 1.0


# ---------------------------------------------------------------------------
# §7.1 Resource acquisition.
# ---------------------------------------------------------------------------

#: Azure D-series-like sizes used throughout the paper: D3=4, D2=2, D1=1 slots.
DEFAULT_VM_SIZES: Tuple[int, ...] = (4, 2, 1)


def _greedy_counts(rho: int, sizes: Sequence[int]) -> List[int]:
    """§7.1 greedy slot counts: as many largest-size VMs as fit, then the
    smallest size that covers the remainder."""
    sizes = sorted(set(sizes), reverse=True)
    largest = sizes[0]
    n_large, rem = divmod(rho, largest)
    counts = [largest] * n_large
    if rem:
        fitting = [s for s in sorted(sizes) if s >= rem]
        counts.append(fitting[0] if fitting else largest)
    return counts


def _proportional_price(classes: Sequence[VmClass]) -> Optional[float]:
    """The common per-slot $/hour when every class is priced proportionally
    to its slots, else ``None`` (→ genuinely heterogeneous costs)."""
    per_slot = classes[0].cost_per_hour / classes[0].slots
    for c in classes:
        if not math.isclose(c.cost_per_hour, per_slot * c.slots,
                            rel_tol=1e-9, abs_tol=1e-12):
            return None
    return per_slot


def _acquire_min_cost(rho: int, classes: Sequence[VmClass]) -> List[VmClass]:
    """Exact min-cost covering multiset over heterogeneous-cost classes:
    pseudo-polynomial DP over remaining slots.  Ties prefer fewer VMs, then
    fewer total slots; reconstruction is deterministic (larger classes
    first)."""
    order = sorted(classes, key=lambda c: (-c.slots, c.name))

    def better(a: Tuple[float, int, int], b: Tuple[float, int, int]) -> bool:
        # float cost sums of equal-value paths can differ by ulps depending
        # on addition order; compare with a tolerance so the (n_vms,
        # total_slots) tie-breaks decide true ties instead of the ulps
        if a[0] < b[0] - 1e-9:
            return True
        if a[0] > b[0] + 1e-9:
            return False
        return (a[1], a[2]) < (b[1], b[2])

    # best[r] = (cost, n_vms, total_slots) to cover r remaining slots
    best: List[Optional[Tuple[float, int, int]]] = [(0.0, 0, 0)]
    choice: List[int] = [-1]
    for r in range(1, rho + 1):
        cell: Optional[Tuple[float, int, int]] = None
        pick = -1
        for ci, c in enumerate(order):
            prev = best[max(0, r - c.slots)]
            cand = (prev[0] + c.cost_per_hour, prev[1] + 1, prev[2] + c.slots)
            if cell is None or better(cand, cell):
                cell, pick = cand, ci
        best.append(cell)
        choice.append(pick)
    chosen: List[VmClass] = []
    r = rho
    while r > 0:
        c = order[choice[r]]
        chosen.append(c)
        r = max(0, r - c.slots)
    chosen.sort(key=lambda c: (-c.slots, c.name))
    return chosen


def acquire_vms(rho: int, vm_sizes: VmSizesArg = DEFAULT_VM_SIZES,
                *, rack_size: int = 32) -> List[VM]:
    """Acquire VMs covering ``rho`` slots (§7.1, generalized to typed
    classes).  Plain int sizes — and class families whose prices are
    slot-proportional — use the paper's greedy (largest size first, then
    the smallest size covering the remainder) and reproduce the unit-slot
    pools bit-identically.  Genuinely heterogeneous costs switch to an
    exact min-cost covering DP.  ``rack_size`` VMs share a rack."""
    if rho <= 0:
        raise ValueError("rho must be positive")
    if not isinstance(vm_sizes, str) \
            and not any(isinstance(s, VmClass) for s in vm_sizes):
        # Legacy §7.1 path: anonymous unit classes, bit-identical pools.
        counts = _greedy_counts(rho, [int(s) for s in vm_sizes])
        return [VM(i, s, rack=i // rack_size) for i, s in enumerate(counts)]
    classes = resolve_vm_classes(vm_sizes)
    if len({c.speed for c in classes}) > 1:
        raise ValueError("acquire_vms pools one speed per acquisition; "
                         "mixed-speed fleets plan per class (min_cost)")
    if _proportional_price(classes) is not None:
        # Uniform $/slot: cost-minimal = slot-minimal, so the §7.1 greedy
        # is cost-optimal and keeps pool shapes identical to the baseline.
        by_slots: Dict[int, VmClass] = {}
        for c in classes:
            by_slots.setdefault(c.slots, c)
        counts = _greedy_counts(rho, list(by_slots))
        chosen = [by_slots[s] for s in counts]
    else:
        chosen = _acquire_min_cost(rho, classes)
    return [VM(i, c.slots, rack=i // rack_size, speed=c.speed,
               vm_class=c.name, cost_per_hour=c.cost_per_hour,
               mem_per_slot=c.mem_per_slot)
            for i, c in enumerate(chosen)]


# ---------------------------------------------------------------------------
# Mapping result with capacity bookkeeping.
# ---------------------------------------------------------------------------

class Mapping:
    """Thread -> slot assignment plus residual-capacity accounting."""

    def __init__(self, vms: Sequence[VM]):
        self.vms: List[VM] = list(vms)
        self.assignment: Dict[Thread, SlotId] = {}
        # Residual capacity views (fractions of a slot).
        self.slot_cpu: Dict[SlotId, float] = {}
        self.slot_mem: Dict[SlotId, float] = {}
        for vm in self.vms:
            for s in vm.slot_ids():
                self.slot_cpu[s] = 1.0
                self.slot_mem[s] = vm.mem_per_slot
        # slot → threads index kept in sync by ``assign``: slot lookups are
        # O(|slot|) instead of O(R) scans over the whole assignment (SAM's
        # ``next_full_slot`` probes every slot, which used to be O(R·S)).
        # Entries are created lazily at a slot's first assignment so dict
        # iteration order matches the old assignment-order scans.
        self._slot_threads: Dict[SlotId, List[Thread]] = {}
        self._slot_counts: Dict[SlotId, Dict[str, int]] = {}

    # -- assignment ----------------------------------------------------------
    def assign(self, thread: Thread, slot: SlotId,
               cpu: float = 0.0, mem: float = 0.0) -> None:
        if thread in self.assignment:
            raise ValueError(f"{thread} already mapped")
        self.assignment[thread] = slot
        self.slot_cpu[slot] -= cpu
        self.slot_mem[slot] -= mem
        self._slot_threads.setdefault(slot, []).append(thread)
        counts = self._slot_counts.setdefault(slot, {})
        counts[thread.task] = counts.get(thread.task, 0) + 1

    # -- views ----------------------------------------------------------------
    def slots(self) -> List[SlotId]:
        return [s for vm in self.vms for s in vm.slot_ids()]

    def used_slots(self) -> List[SlotId]:
        used = {s for s, ts in self._slot_threads.items() if ts}
        return [s for s in self.slots() if s in used]

    def threads_on_slot(self, slot: SlotId) -> List[Thread]:
        return list(self._slot_threads.get(slot, ()))

    def slot_task_counts(self) -> Dict[SlotId, Dict[str, int]]:
        """Per-slot thread counts grouped by task — the co-location structure
        consumed by the predictor/simulator."""
        return {s: dict(c) for s, c in self._slot_counts.items() if c}

    def vm_cpu_available(self, vm: VM) -> float:
        return sum(self.slot_cpu[s] for s in vm.slot_ids())

    def vm_mem_available(self, vm: VM) -> float:
        return sum(self.slot_mem[s] for s in vm.slot_ids())

    def mixed_slots(self) -> int:
        """Number of slots hosting threads of more than one task (SAM bounds
        this by |V|, §7.4)."""
        return sum(1 for counts in self.slot_task_counts().values()
                   if len(counts) > 1)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"Mapping(threads={len(self.assignment)}, "
                f"slots={len(self.used_slots())}/{len(self.slots())})")


def make_threads(alloc: Allocation) -> List[Thread]:
    """Materialize the thread set R from an allocation."""
    threads: List[Thread] = []
    for name, ta in alloc.tasks.items():
        threads.extend(Thread(name, k) for k in range(ta.threads))
    return threads


# ---------------------------------------------------------------------------
# Algorithm 4: Default Storm Mapping (round-robin).
# ---------------------------------------------------------------------------

def map_dsm(dag: Dataflow, alloc: Allocation, vms: Sequence[VM],
            models: Optional[ModelLibrary] = None) -> Mapping:
    """Round-robin threads over slots, resource-oblivious (Alg. 4)."""
    mapping = Mapping(vms)
    slots = mapping.slots()
    threads = make_threads(alloc)
    for n, thread in enumerate(threads):
        mapping.assign(thread, slots[n % len(slots)])
    return mapping


# ---------------------------------------------------------------------------
# Algorithm 5: R-Storm Mapping (resource- and network-aware best fit).
# ---------------------------------------------------------------------------

def map_rsm(dag: Dataflow, alloc: Allocation, vms: Sequence[VM],
            models: ModelLibrary, *,
            w_cpu: float = 1.0, w_mem: float = 1.0, w_net: float = 1.0) -> Mapping:
    """R-Storm mapping (Alg. 5).

    One sweep maps one thread of every task in topological order; candidate
    VMs are sorted by the Euclidean distance between the VM's *available*
    resources and the thread's single-thread needs (``c_bar, m_bar``), plus a
    network term from the last-mapped VM.  Storm semantics: CPU% pools across
    a VM's slots, memory% binds per slot.
    """
    mapping = Mapping(vms)
    # Per-VM availability ARRAYS (Storm lets threads use any core of the VM,
    # so CPU% pools VM-wide).  The R-Storm candidate order for one thread is
    # then a single vectorized lexsort over these arrays instead of a Python
    # ``sorted`` whose key closure re-reads dicts per comparison — the old
    # inner sort cost O(V log V) *Python-object* work per thread.  A full
    # once-per-sweep hoist of the sort itself would change placements: the
    # distance depends on availability (updated by every assignment) and on
    # the last-mapped VM's network term, so the *order* is recomputed per
    # thread, but as one O(V) array pass.
    avail_cpu = np.array([vm.num_slots * 1.0 for vm in vms])
    avail_mem = np.array([vm.num_slots * vm.mem_per_slot for vm in vms])
    vm_ids = np.array([vm.id for vm in vms], dtype=int)
    vm_racks = np.array([vm.rack for vm in vms], dtype=int)
    remaining: Dict[str, int] = {n: ta.threads for n, ta in alloc.tasks.items()}
    next_idx: Dict[str, int] = {n: 0 for n in alloc.tasks}
    ref: Optional[VM] = vms[0] if vms else None
    order = [t.name for t in dag.topo_order()]
    # per-thread needs are rate-independent: hoist them out of the sweep loop
    needs: Dict[str, Tuple[float, float]] = {}
    for name, ta in alloc.tasks.items():
        model = models[ta.kind]
        if ta.bundle_size > 1:
            # MBA-style allocation: charge the model-amortized per-thread
            # resources at the bundle operating point (a 50-thread blob
            # bundle uses ~96% of a slot, not 50 x 23.9% — §8.5 maps
            # 25-30 such threads per slot under RSM)
            needs[name] = (model.C(ta.bundle_size) / ta.bundle_size,
                           model.M(ta.bundle_size) / ta.bundle_size)
        else:
            needs[name] = (model.C(1), model.M(1))

    while sum(remaining.values()) > 0:
        progressed = False
        for name in order:
            if remaining[name] <= 0:
                continue
            c_bar, m_bar = needs[name]
            # R-Storm distance on available resources, one array pass; the
            # lexsort (dist primary, VM id tiebreak) reproduces the old
            # ``sorted(vms, key=lambda v: (dist(v), v.id))`` order exactly
            if ref is None:
                net = np.zeros(len(vms))
            else:
                net = np.where(vm_ids == ref.id, 0.0,
                               np.where(vm_racks == ref.rack, 0.5, 1.0))
            d = (w_mem * (avail_mem - m_bar) ** 2
                 + w_cpu * (avail_cpu - c_bar) ** 2 + w_net * net)
            chosen_slot: Optional[SlotId] = None
            chosen_vm: Optional[VM] = None
            chosen_i = -1
            for i in np.lexsort((vm_ids, d)):
                if avail_cpu[i] + 1e-9 < c_bar:
                    continue
                vm = vms[i]
                # best-fit slot within the VM by remaining memory
                fitting = [s for s in vm.slot_ids()
                           if mapping.slot_mem[s] + 1e-9 >= m_bar]
                if not fitting:
                    continue
                chosen_slot = min(fitting, key=lambda s: (mapping.slot_mem[s], s.slot))
                chosen_vm = vm
                chosen_i = int(i)
                break
            if chosen_slot is None:
                raise InsufficientResourcesError(name)
            thread = Thread(name, next_idx[name])
            next_idx[name] += 1
            mapping.assign(thread, chosen_slot, cpu=0.0, mem=m_bar)
            avail_cpu[chosen_i] -= c_bar
            avail_mem[chosen_i] -= m_bar
            remaining[name] -= 1
            ref = chosen_vm
            progressed = True
        if not progressed:  # pragma: no cover - defensive
            raise InsufficientResourcesError("<any>", "no progress in RSM sweep")
    return mapping


# ---------------------------------------------------------------------------
# Algorithm 6: Slot-Aware Mapping (gang scheduling of thread bundles).
# ---------------------------------------------------------------------------

def _sam_bundle_plan(ta: TaskAllocation, models: ModelLibrary) -> Tuple[int, int, float, float]:
    """(bundle_size, full_bundles, partial_cpu, partial_mem) for a task.

    MBA allocations carry this directly; for other allocators (not used by
    the paper with SAM, but supported) it is derived from the model.
    """
    model = models[ta.kind]
    if ta.bundle_size > 0:  # MBA bookkeeping
        partial_cpu = ta.cpu - ta.full_bundles * 1.0
        partial_mem = ta.mem - ta.full_bundles * 1.0
        return ta.bundle_size, ta.full_bundles, max(0.0, partial_cpu), max(0.0, partial_mem)
    tau_hat = model.tau_hat
    full = ta.threads // tau_hat
    rem = ta.threads - full * tau_hat
    return tau_hat, full, (model.C(rem) if rem else 0.0), (model.M(rem) if rem else 0.0)


def map_sam(dag: Dataflow, alloc: Allocation, vms: Sequence[VM],
            models: ModelLibrary) -> Mapping:
    """Slot-Aware Mapping (Alg. 6).

    Full bundles of ``tau_hat`` threads are gang-mapped to *exclusive* empty
    slots (the bundle saturates the slot by construction, so it is charged
    100/100); the final partial bundle best-fits into a partially used slot.
    At most one partial bundle per task ever shares a slot, bounding
    mixed-task slots.
    """
    mapping = Mapping(vms)
    next_idx: Dict[str, int] = {n: 0 for n in alloc.tasks}
    plans = {n: _sam_bundle_plan(ta, models) for n, ta in alloc.tasks.items()}
    # Full bundles (slot-saturating, charged 100/100 by MBA) go to exclusive
    # slots; everything else is the partial bundle with its model-derived
    # residual charge.  Keying off the allocation's bundle bookkeeping (not
    # a bare tau_i >= tau_hat test) keeps trailing sub-peak thread groups
    # out of exclusive slots.
    remaining_full: Dict[str, int] = {n: plans[n][1] for n in alloc.tasks}
    partial_threads: Dict[str, int] = {
        n: alloc.tasks[n].threads - plans[n][1] * plans[n][0]
        for n in alloc.tasks}
    partial_need: Dict[str, Tuple[float, float]] = {
        n: (plans[n][2], plans[n][3]) for n in alloc.tasks}
    order = [t.name for t in dag.topo_order()]
    slot_list = mapping.slots()
    cursor = 0  # GetNextFullSlot scans forward from the last exclusive slot

    def next_full_slot() -> Optional[SlotId]:
        nonlocal cursor
        for k in range(len(slot_list)):
            s = slot_list[(cursor + k) % len(slot_list)]
            if mapping.slot_cpu[s] >= 1.0 - 1e-9 and not mapping.threads_on_slot(s):
                cursor = (cursor + k) % len(slot_list)
                return s
        return None

    def best_fit_slot(cpu: float, mem: float) -> Optional[SlotId]:
        fitting = [s for s in slot_list
                   if mapping.slot_cpu[s] + 1e-9 >= cpu
                   and mapping.slot_mem[s] + 1e-9 >= mem]
        if not fitting:
            return None
        return min(fitting, key=lambda s: (mapping.slot_cpu[s] + mapping.slot_mem[s],
                                           s.vm, s.slot))

    while sum(remaining_full.values()) + sum(partial_threads.values()) > 0:
        progressed = False
        for name in order:
            bundle, _, _, _ = plans[name]
            if remaining_full[name] > 0:
                s = next_full_slot()
                if s is None:
                    raise InsufficientResourcesError(name)
                for _ in range(bundle):
                    mapping.assign(Thread(name, next_idx[name]), s)
                    next_idx[name] += 1
                # the bundle owns the slot outright
                mapping.slot_cpu[s] = 0.0
                mapping.slot_mem[s] = 0.0
                remaining_full[name] -= 1
                progressed = True
            elif partial_threads[name] > 0:
                cpu, mem = partial_need[name]
                s = best_fit_slot(cpu, mem)
                if s is None:
                    raise InsufficientResourcesError(name)
                for _ in range(partial_threads[name]):
                    mapping.assign(Thread(name, next_idx[name]), s)
                    next_idx[name] += 1
                mapping.slot_cpu[s] -= cpu
                mapping.slot_mem[s] -= mem
                partial_threads[name] = 0
                progressed = True
        if not progressed:  # pragma: no cover - defensive
            raise InsufficientResourcesError("<any>", "no progress in SAM sweep")
    return mapping


MAPPERS = {
    "dsm": map_dsm,
    "rsm": map_rsm,
    "sam": map_sam,
}


# ---------------------------------------------------------------------------
# Candidate-mapping helpers for the simulation-guided search (repro.core.search).
# ---------------------------------------------------------------------------

def remap_threads(mapping: Mapping,
                  assignment: TMapping[Thread, SlotId]) -> Mapping:
    """A fresh :class:`Mapping` on the same VM pool with the given
    thread→slot assignment.

    The residual cpu/mem bookkeeping is NOT reconstructed (it is
    mapper-specific accounting); consumers of a *finished* mapping — the
    predictor, simulator, and search evaluator — read only ``vms`` and the
    assignment/co-location views.
    """
    out = Mapping(mapping.vms)
    for thread, slot in assignment.items():
        out.assign(thread, slot)
    return out


def mapping_signature(mapping: Mapping) -> Tuple:
    """Canonical co-location signature, invariant to slot renaming within a
    VM: per used slot, ``(vm id, sorted (task, count) contents)``, sorted.
    Two mappings with equal signatures are physically indistinguishable to
    the predictor and simulator (same groups, same co-location, same hop
    structure), so the candidate pool dedupes on it."""
    return tuple(sorted(
        (slot.vm, tuple(sorted(counts.items())))
        for slot, counts in mapping.slot_task_counts().items()))


def local_moves(mapping: Mapping, *, n_moves: int = 8, seed: int = 0,
                max_tries: Optional[int] = None) -> List[Mapping]:
    """Seeded local perturbations of a base mapping: *swap* the whole thread
    contents of two used slots (preferring cross-VM pairs — same-VM swaps
    are physically identity moves and dedupe away), or *migrate* one task's
    thread bundle to an empty slot.

    Both move kinds preserve every per-(task, slot) group size, so all
    candidates derived from one base share the base's group-shape signature
    — the property the search's shape-bucketed vmap evaluation relies on to
    batch them into ONE compiled kernel.  Returns up to ``n_moves`` distinct
    (by :func:`mapping_signature`) new mappings.
    """
    rng = random.Random(seed)
    out: List[Mapping] = []
    seen = {mapping_signature(mapping)}
    used = mapping.used_slots()
    used_set = set(used)
    empty = [s for s in mapping.slots() if s not in used_set]
    tries = max_tries if max_tries is not None else max(20, n_moves * 20)
    for _ in range(tries):
        if len(out) >= n_moves:
            break
        assignment = dict(mapping.assignment)
        if empty and (len(used) < 2 or rng.random() < 0.5):
            # migrate one (task, slot) bundle to an empty slot
            src = rng.choice(used)
            tasks_on = sorted({t.task for t in mapping.threads_on_slot(src)})
            task = rng.choice(tasks_on)
            dst = rng.choice(empty)
            for t in mapping.threads_on_slot(src):
                if t.task == task:
                    assignment[t] = dst
        elif len(used) >= 2:
            # swap two used slots' whole contents, biased to cross-VM pairs
            a, b = rng.sample(used, 2)
            if a.vm == b.vm:
                cross = [s for s in used if s.vm != a.vm]
                if cross:
                    b = rng.choice(cross)
            for t, s in mapping.assignment.items():
                if s == a:
                    assignment[t] = b
                elif s == b:
                    assignment[t] = a
        else:
            break   # single used slot and nowhere to move: no moves exist
        cand = remap_threads(mapping, assignment)
        sig = mapping_signature(cand)
        if sig in seen:
            continue
        seen.add(sig)
        out.append(cand)
    return out
