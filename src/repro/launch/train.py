"""Training driver: data pipeline (scheduled by MBA+SAM) -> train loop with
checkpoint/restart fault tolerance.

CPU-scale usage (runs a ~100M-param model for a few hundred steps):

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \\
        --scale 100m --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On a real cluster the same driver runs under the production mesh
(--mesh single|multi) with per-host data feeding; elastic restart is
exercised by killing and relaunching with the same --ckpt-dir.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data import SyntheticTokens, TokenPipeline, plan_pipeline
from ..models import default_env, get_model
from ..train import AdamWConfig, Checkpointer, init_train_state, make_train_step


def scale_config(cfg, scale: str):
    """Derive a runnable-size config of the same family."""
    if scale == "full":
        return cfg
    presets = {
        "100m": dict(num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
                     head_dim=64, d_ff=2048, vocab_size=32768),
        "10m": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                    head_dim=64, d_ff=1024, vocab_size=8192),
    }
    kw = dict(presets[scale])
    if cfg.family in ("ssm", "hybrid"):
        kw.pop("num_heads"), kw.pop("num_kv_heads"), kw.pop("head_dim")
        if cfg.family == "ssm":
            kw["d_ff"] = 0
    if cfg.family == "moe":
        kw.update(num_experts=min(cfg.num_experts, 8),
                  experts_per_token=min(cfg.experts_per_token, 2),
                  d_ff=512)
    if cfg.family == "audio":
        kw.update(encoder_layers=4, encoder_seq=64)
    if cfg.family == "vlm":
        kw.update(num_patches=16)
    return dataclasses.replace(cfg, **kw, name=cfg.name + f"-{scale}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--scale", default="100m", choices=["10m", "100m", "full"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--real-pipeline", action="store_true",
                    help="use the scheduled host data pipeline instead of "
                         "synthetic tokens")
    args = ap.parse_args()

    cfg = scale_config(get_config(args.arch), args.scale)
    api = get_model(cfg)
    env = default_env()
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    # -- data pipeline, scheduled by the paper's scheduler ----------------
    tokens_per_step = args.batch * args.seq
    if args.real_pipeline:
        docs_per_sec = tokens_per_step * 2.0   # ~2 steps/s target, ~1 doc/512 tok
        schedule = plan_pipeline(docs_per_sec)
        print("data pipeline plan:",
              {t.task: t.threads for t in schedule.allocation.tasks.values()},
              f"on {schedule.acquired_slots} host slots")
        pipe = TokenPipeline(args.seq, args.batch, schedule)
        batches = pipe.batches(args.steps)
        def next_batch():
            return next(batches)
    else:
        src = SyntheticTokens(args.seq, args.batch, cfg.vocab_size)
        def next_batch():
            return src.next()

    # -- train state (restore if a checkpoint exists: fault tolerance) ----
    opt = AdamWConfig(lr=args.lr, warmup=max(10, args.steps // 20),
                      total_steps=args.steps, schedule=cfg.lr_schedule)
    state = init_train_state(api, jax.random.PRNGKey(0), opt)
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir)
        if ckpt.latest_step() is not None:
            state, start_step, _ = ckpt.restore(state)
            print(f"restored checkpoint at step {start_step}")

    step_fn = jax.jit(make_train_step(api, env, opt,
                                      microbatches=args.microbatches),
                      donate_argnums=0)

    t0 = time.perf_counter()
    tokens_seen = 0
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next_batch().items()}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        state, metrics = step_fn(state, batch)
        tokens_seen += tokens_per_step
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"acc {float(metrics['accuracy']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"tok/s {tokens_seen / max(dt, 1e-9):.0f}")
        if ckpt and step > start_step and step % args.ckpt_every == 0:
            ckpt.save(step, state)
            print(f"checkpointed step {step}")
    if ckpt:
        ckpt.save(args.steps, state)
        ckpt.wait()
    print("done.")


if __name__ == "__main__":
    main()
