"""End-to-end training driver example: train a ~100M-param minicpm-family
model for a few hundred steps on CPU with checkpointing.

Run:  PYTHONPATH=src python examples/train_lm.py
(thin wrapper over python -m repro.launch.train; see that module for flags)
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "minicpm-2b", "--scale", "100m",
                "--steps", "200", "--batch", "4", "--seq", "256",
                "--ckpt-dir", "/tmp/repro_ckpt"] + sys.argv[1:]
    main()
