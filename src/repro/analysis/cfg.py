"""Per-function control-flow graphs and reaching-definitions data flow.

The interprocedural engine (:mod:`repro.analysis.flow`) needs two local
facts about a function body:

* a **control-flow graph** — basic blocks of statements linked by
  successor edges, with loops/branches/try lowered the standard way;
* **reaching definitions** over that CFG — for a variable use, which
  assignments *may* have produced its value (value provenance).

Both are deliberately small: statement-granular blocks, a monotone
union/worklist solve, and a query API (:meth:`ReachingDefs.may_values`)
that returns the *value expressions* of the reaching assignments so
analyzers can pattern-match provenance (e.g. "was this name possibly
bound to a ``jnp`` expression?" for JAX111, "was it bound to a call of
factory ``F`` ?" for JAX112).

Nested function/class bodies are opaque: a nested ``def`` is a single
definition event of its name; its body belongs to its own CFG.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

#: A definition event: (names defined, value expression or None=unknown).
_Defs = List[Tuple[str, Optional[ast.expr]]]

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                ast.ClassDef)


def _walk_same_scope(node: ast.AST) -> Iterator[ast.AST]:
    """Walk ``node``'s subtree without descending into nested scopes."""
    yield node
    if isinstance(node, _SCOPE_NODES):
        return
    for child in ast.iter_child_nodes(node):
        yield from _walk_same_scope(child)


def _target_names(target: ast.expr) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(_target_names(elt))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return []          # attribute / subscript targets are not local names


def _event_defs(node: ast.AST) -> _Defs:
    """Names defined by one CFG event, with their value expression."""
    defs: _Defs = []
    if isinstance(node, ast.Assign):
        for tgt in node.targets:
            names = _target_names(tgt)
            # a tuple unpack loses the per-name expression: keep the RHS
            # only for the single-name form where it IS the value
            value = node.value if isinstance(tgt, ast.Name) else None
            defs.extend((n, value) for n in names)
    elif isinstance(node, ast.AnnAssign):
        if isinstance(node.target, ast.Name) and node.value is not None:
            defs.append((node.target.id, node.value))
    elif isinstance(node, ast.AugAssign):
        defs.extend((n, None) for n in _target_names(node.target))
    elif isinstance(node, ast.For):
        defs.extend((n, None) for n in _target_names(node.target))
    elif isinstance(node, ast.withitem):
        if node.optional_vars is not None:
            defs.extend((n, node.context_expr)
                        for n in _target_names(node.optional_vars))
    elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
        defs.append((node.name, None))
    elif isinstance(node, ast.Import):
        for alias in node.names:
            defs.append(((alias.asname or alias.name).split(".")[0], None))
    elif isinstance(node, ast.ImportFrom):
        for alias in node.names:
            defs.append((alias.asname or alias.name, None))
    elif isinstance(node, ast.ExceptHandler):
        if node.name:
            defs.append((node.name, None))
    elif isinstance(node, ast.NamedExpr):
        if isinstance(node.target, ast.Name):
            defs.append((node.target.id, node.value))
    return defs


class BasicBlock:
    """A straight-line run of definition/use events."""

    __slots__ = ("bid", "events", "succ")

    def __init__(self, bid: int) -> None:
        self.bid = bid
        self.events: List[ast.AST] = []
        self.succ: List["BasicBlock"] = []

    def link(self, other: "BasicBlock") -> None:
        if other not in self.succ:
            self.succ.append(other)

    def __repr__(self) -> str:   # pragma: no cover - debug aid
        return (f"B{self.bid}({len(self.events)} ev -> "
                f"{[b.bid for b in self.succ]})")


class CFG:
    """Control-flow graph of one function body (statement granularity)."""

    def __init__(self, fn: ast.AST, body: List[ast.stmt]) -> None:
        self.fn = fn
        self.blocks: List[BasicBlock] = []
        self.entry = self._new()
        self.exit = self._new()
        # (head, after) per enclosing loop, innermost last
        self._loops: List[Tuple[BasicBlock, BasicBlock]] = []
        end = self._visit_body(body, self.entry)
        if end is not None:
            end.link(self.exit)

    def _new(self) -> BasicBlock:
        b = BasicBlock(len(self.blocks))
        self.blocks.append(b)
        return b

    def _visit_body(self, stmts: List[ast.stmt],
                    cur: Optional[BasicBlock]) -> Optional[BasicBlock]:
        for stmt in stmts:
            if cur is None:          # unreachable code: isolated block
                cur = self._new()
            cur = self._visit(stmt, cur)
        return cur

    def _visit(self, stmt: ast.stmt,
               cur: BasicBlock) -> Optional[BasicBlock]:
        if isinstance(stmt, (ast.Return, ast.Raise)):
            cur.events.append(stmt)
            cur.link(self.exit)
            return None
        if isinstance(stmt, ast.Break):
            if self._loops:
                cur.link(self._loops[-1][1])
            return None
        if isinstance(stmt, ast.Continue):
            if self._loops:
                cur.link(self._loops[-1][0])
            return None
        if isinstance(stmt, ast.If):
            cur.events.append(stmt.test)
            then = self._new()
            cur.link(then)
            end_then = self._visit_body(stmt.body, then)
            join = self._new()
            if stmt.orelse:
                other = self._new()
                cur.link(other)
                end_other = self._visit_body(stmt.orelse, other)
                if end_other is not None:
                    end_other.link(join)
            else:
                cur.link(join)
            if end_then is not None:
                end_then.link(join)
            return join
        if isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            head = self._new()
            cur.link(head)
            if isinstance(stmt, ast.While):
                head.events.append(stmt.test)
            else:
                head.events.append(stmt)      # For defines its target
            after = self._new()
            body = self._new()
            head.link(body)
            self._loops.append((head, after))
            end = self._visit_body(stmt.body, body)
            self._loops.pop()
            if end is not None:
                end.link(head)
            if stmt.orelse:                   # runs on normal loop exit
                or_start = self._new()
                head.link(or_start)
                end_or = self._visit_body(stmt.orelse, or_start)
                if end_or is not None:
                    end_or.link(after)
            else:
                head.link(after)              # zero-iteration path
            return after
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                cur.events.append(item)
            return self._visit_body(stmt.body, cur)
        if isinstance(stmt, ast.Try):
            end_body = self._visit_body(stmt.body, cur)
            tails: List[BasicBlock] = []
            for handler in stmt.handlers:
                hb = self._new()
                cur.link(hb)                  # any stmt in body may raise
                hb.events.append(handler)
                end_h = self._visit_body(handler.body, hb)
                if end_h is not None:
                    tails.append(end_h)
            if stmt.orelse and end_body is not None:
                end_body = self._visit_body(stmt.orelse, end_body)
            if end_body is not None:
                tails.append(end_body)
            join: Optional[BasicBlock]
            if stmt.finalbody:
                join = self._new()
                for t in tails:
                    t.link(join)
                return self._visit_body(stmt.finalbody, join)
            if not tails:
                return None
            join = self._new()
            for t in tails:
                t.link(join)
            return join
        # simple statement (incl. nested defs, which define their name)
        cur.events.append(stmt)
        return cur


class ReachingDefs:
    """May-reaching definitions over a :class:`CFG`, with value queries."""

    def __init__(self, fn: ast.AST, body: List[ast.stmt],
                 params: Tuple[str, ...] = ()) -> None:
        self.cfg = CFG(fn, body)
        # def site -> (block id, event index); values indexed the same way
        self._values: Dict[Tuple[int, int, str],
                           Optional[ast.expr]] = {}
        self._where: Dict[int, Tuple[int, int]] = {}     # id(node) -> site
        gen: Dict[int, Dict[str, Set[Tuple[int, int]]]] = {}
        for block in self.cfg.blocks:
            g: Dict[str, Set[Tuple[int, int]]] = {}
            for idx, ev in enumerate(block.events):
                for name, value in _event_defs(ev):
                    g[name] = {(block.bid, idx)}
                    self._values[(block.bid, idx, name)] = value
                for sub in _walk_same_scope(ev):
                    self._where.setdefault(id(sub), (block.bid, idx))
            gen[block.bid] = g
        entry_defs: Dict[str, Set[Tuple[int, int]]] = {
            p: {(-1, -1)} for p in params}
        for p in params:
            self._values[(-1, -1, p)] = None
        # worklist solve: IN[b] = union OUT[preds]; OUT = gen over IN
        self._in: Dict[int, Dict[str, Set[Tuple[int, int]]]] = {
            b.bid: {} for b in self.cfg.blocks}
        self._in[self.cfg.entry.bid] = dict(entry_defs)
        out: Dict[int, Dict[str, Set[Tuple[int, int]]]] = {}
        work = [b.bid for b in self.cfg.blocks]
        by_id = {b.bid: b for b in self.cfg.blocks}
        while work:
            bid = work.pop()
            block = by_id[bid]
            o = dict(self._in[bid])
            for name, sites in gen[bid].items():
                o[name] = set(sites)
            if out.get(bid) == o:
                continue
            out[bid] = o
            for succ in block.succ:
                tgt = self._in[succ.bid]
                changed = False
                for name, sites in o.items():
                    have = tgt.setdefault(name, set())
                    if not sites <= have:
                        have.update(sites)
                        changed = True
                if changed and succ.bid not in work:
                    work.append(succ.bid)

    def may_values(self, use: ast.AST, name: str) -> List[Optional[ast.expr]]:
        """Value expressions ``name`` may hold at ``use`` (None=opaque).

        Returns ``[]`` when the name has no local definition reaching the
        use (a global, builtin, or free variable).
        """
        site = self._where.get(id(use))
        if site is None:
            return []
        bid, idx = site
        block = self.cfg.blocks[bid]
        sites = set(self._in[bid].get(name, set()))
        for i in range(idx):                 # earlier events in the block
            for n, _ in _event_defs(block.events[i]):
                if n == name:
                    sites = {(bid, i)}
        out: List[Optional[ast.expr]] = []
        for s in sorted(sites):
            out.append(self._values.get((s[0], s[1], name)))
        return out
