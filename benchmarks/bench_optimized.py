"""§Perf — baseline vs optimized roofline comparison.

Reads `experiments/dryrun` (baseline) and `experiments/optimized` (the
--attn-chunk/--seq-shard/--lean-optimizer sweep) and prints the
before/after table of EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import glob
import json
import os

from .common import Table

BASE_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")
OPT_DIR = os.environ.get("OPTIMIZED_DIR", "experiments/optimized")


def run() -> dict:
    opts = {}
    for p in sorted(glob.glob(os.path.join(OPT_DIR, "pod16x16-*.json"))):
        c = json.load(open(p))
        if c["status"] == "ok":
            opts[(c["arch"], c["shape"])] = c
    if not opts:
        print(f"\n== §Perf baseline-vs-optimized: no artifacts in {OPT_DIR} ==")
        return {"cells": 0}
    tbl = Table(["arch", "shape", "bound_s base→opt", "delta%",
                 "GiB/dev base→opt", "useful base→opt"])
    improved = 0
    for (arch, shape), o in sorted(opts.items()):
        bp = os.path.join(BASE_DIR, f"pod16x16-{arch}-{shape}.json")
        if not os.path.exists(bp):
            continue
        b = json.load(open(bp))
        if b["status"] != "ok":
            continue
        sb = b["roofline"]["step_s_bound"]
        so = o["roofline"]["step_s_bound"]
        mb = b["memory"]["total_per_device"] / 2**30
        mo = o["memory"]["total_per_device"] / 2**30
        delta = 100 * (1 - so / sb)
        improved += delta > 5
        tbl.add(arch, shape, f"{sb:.1f}→{so:.1f}", round(delta, 1),
                f"{mb:.1f}→{mo:.1f}",
                f"{b['useful_flops_ratio']:.2f}→{o['useful_flops_ratio']:.2f}")
    tbl.show("§Perf: baseline vs optimized (single-pod)")
    print("NOTE (EXPERIMENTS.md §Perf iter 6 audit): for attention archs the "
          "bound_s deltas are inflated by the inner-chunk-scan counting "
          "artifact; the GiB/dev column is buffer-assignment truth, as are "
          "attention-free rows (mamba2) and the decode row.")
    return {"cells": len(opts), "improved_gt5pct": improved}


if __name__ == "__main__":
    run()
