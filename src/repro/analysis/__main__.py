"""CLI for the static-analysis layer.

Usage::

    python -m repro.analysis src/              # lint sources (default: src/)
    python -m repro.analysis lint src/ tests/  # same, explicit subcommand
    python -m repro.analysis flow src/         # interprocedural analyses
    python -m repro.analysis prove             # static rate-stability prover
    python -m repro.analysis prove --simulate  # ... cross-checked vs sim
    python -m repro.analysis --list-rules      # full rule catalog
    python -m repro.analysis --verify-smoke    # verifier over paper fixtures
    python -m repro.analysis flow src/ --json  # {"version": 2, "findings"}
    python -m repro.analysis flow src/ --sarif out.sarif

Exit status is pinned so CI can gate on it:

* **0** — clean, or WARNING-severity findings only;
* **1** — at least one ERROR-severity finding (lint/flow rule hit,
  verifier error, prover disagreement under ``prove --simulate``);
* **2** — usage error or source that failed to parse (``LINT000``).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence

from repro.core.diagnostics import Severity, Violation
from repro.analysis.lint import RULES, lint_paths

JSON_VERSION = 2       # bumped when the --json finding shape changes

_SUBCOMMANDS = ("lint", "flow", "prove")


def _print(violations: List[Violation], as_json: bool) -> None:
    if as_json:
        print(json.dumps({
            "version": JSON_VERSION,
            "findings": [{
                "code": v.code, "severity": v.severity.value,
                "artifact": v.artifact, "path": v.path, "detail": v.detail,
            } for v in violations]}, indent=2))
    else:
        for v in violations:
            print(v)


def _exit_code(violations: List[Violation]) -> int:
    """Pinned mapping: parse failure > rule errors > warnings-only."""
    if any(v.code == "LINT000" for v in violations):
        return 2
    if any(v.severity is Severity.ERROR for v in violations):
        return 1
    return 0


def _finish(violations: List[Violation], label: str, as_json: bool,
            sarif: Optional[str]) -> int:
    _print(violations, as_json)
    if sarif:
        from repro.analysis.sarif import write_sarif
        write_sarif(sarif, violations)
        print(f"{label}: wrote {sarif}", file=sys.stderr)
    code = _exit_code(violations)
    if code:
        print(f"{label}: {len(violations)} finding(s)", file=sys.stderr)
    elif violations:
        print(f"{label}: clean ({len(violations)} warning(s))")
    else:
        print(f"{label}: clean")
    return code


def list_rules() -> int:
    from repro.analysis.flow import FLOW_RULES
    from repro.analysis.prove import RATE_RULES
    for rule in RULES:
        head = (rule.doc or "").strip().splitlines()
        print(f"{rule.code}  {rule.name}: {head[0] if head else ''}")
    print("LINT001  unknown-suppression-code: a `lint: ok` comment names "
          "a code no rule emits")
    for code, name, summary in FLOW_RULES + RATE_RULES:
        print(f"{code}  {name}: {summary}")
    return 0


def verify_smoke() -> List[Violation]:
    """Build the paper fixtures fresh and run every verifier pass on them.

    Covers all seven passes: the micro/app DAG zoo, the paper model
    tables, a deep single-DAG plan, a deep 3-DAG ``plan_fleet``, and a
    short event trace driven through a validating ``FleetController``."""
    from repro.core import (ALL_DAGS, DagArrive, DagDepart, FleetController,
                            RateChange, paper_library, plan, plan_fleet)
    from repro.core.online import EventTrace
    from repro.analysis import verify as V

    lib = paper_library()
    out: List[Violation] = []
    out.extend(V.verify_models(lib))
    dags = {}
    for name, maker in ALL_DAGS.items():
        dag = maker()
        dags[name] = dag
        out.extend(V.verify_dag(dag))

    sched = plan(dags["linear"], 40.0, lib, validate=False)
    out.extend(V.verify_dag(sched.dag))
    out.extend(V.verify_allocation(sched.allocation, sched.dag, lib))
    out.extend(V.verify_schedule(sched))

    fleet_dags = {k: dags[k] for k in ("linear", "diamond", "star")}
    fp = plan_fleet(fleet_dags, lib, budget_slots=30, validate=False)
    out.extend(V.verify_fleet_plan(fp, lib, deep=True))

    trace = EventTrace([
        (0.0, DagArrive("linear", dags["linear"], weight=1.0)),
        (1.0, DagArrive("diamond", dags["diamond"], weight=1.0)),
        (2.0, RateChange("linear", max_rate=80.0)),
        (3.0, DagDepart("diamond")),
    ])
    out.extend(V.verify_trace(trace))
    ctl = FleetController(lib, budget_slots=24, validate=False)
    for t, ev in trace:
        ctl.apply(ev, at=t)
    out.extend(V.verify_controller(ctl, deep=True))
    return out


def run_prove(args: argparse.Namespace) -> int:
    """Plan a paper-fixture fleet, prove the whole rate sweep, and (with
    ``--simulate``) cross-check every decided cell against the
    co-simulation's stable/unstable verdict."""
    import numpy as np
    from repro.core import (DagArrive, FleetController, diamond_dag,
                            linear_dag, paper_library, star_dag)
    from repro.analysis.prove import (PROVED_STABLE, PROVED_UNSTABLE,
                                      prove_fleet)

    lib = paper_library()
    ctl = FleetController(lib, budget_slots=args.budget_slots, mapper="sam",
                          step=10.0, max_rate=args.max_rate, validate=False)
    for name, dag in (("linear", linear_dag()), ("diamond", diamond_dag()),
                      ("star", star_dag())):
        ctl.apply(DagArrive(name, dag))

    fracs = np.linspace(0.25, 1.25, 9)
    proofs = prove_fleet(ctl.plan, ctl.models, fractions=fracs)
    violations: List[Violation] = []
    decided = total = 0
    for name, prs in sorted(proofs.items()):
        cells = []
        for p in prs:
            total += 1
            decided += p.proved
            mark = {PROVED_STABLE: "S", PROVED_UNSTABLE: "U"}.get(
                p.verdict, "?")
            cells.append(f"{p.omega:g}:{mark}")
            violations.extend(p.violations)
        print(f"prove: {name}  " + "  ".join(cells))
    print(f"prove: {decided}/{total} cells decided "
          "(S proved stable, U proved unstable, ? unprovable)")

    if args.simulate:
        report = ctl.cosimulate(fractions=fracs, duration=8.0, dt=0.1,
                                engine="numpy")
        mismatches = 0
        for name, prs in proofs.items():
            entry = report.entries.get(name)
            if entry is None:
                continue
            for k, p in enumerate(prs):
                if not p.proved:
                    continue
                sim_stable = entry.results[k].stable
                want = p.verdict == PROVED_STABLE
                if sim_stable != want:
                    mismatches += 1
                    violations.append(Violation(
                        "RATE309", Severity.ERROR, name,
                        f"{name}@{p.omega:g}",
                        f"prover says {p.verdict} but the co-simulation "
                        f"says {'stable' if sim_stable else 'unstable'}"))
        print(f"prove: simulate cross-check — {mismatches} mismatch(es) "
              f"over {total} cells")

    if args.json:
        print(json.dumps({
            "version": JSON_VERSION,
            "cells": {name: [{
                "omega": p.omega, "verdict": p.verdict,
                "margin": p.margin, "binding": p.binding,
            } for p in prs] for name, prs in sorted(proofs.items())},
            "findings": [{
                "code": v.code, "severity": v.severity.value,
                "artifact": v.artifact, "path": v.path, "detail": v.detail,
            } for v in violations]}, indent=2))
    if args.sarif:
        from repro.analysis.sarif import write_sarif
        write_sarif(args.sarif, violations)
        print(f"prove: wrote {args.sarif}", file=sys.stderr)

    # RATE301/304 on genuinely-unstable cells are expected output here, not
    # failures: the command's contract is "decide and report".  Only a
    # cross-check mismatch (RATE309) fails the run.
    return 1 if any(v.code == "RATE309" for v in violations) else 0


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-hazard/race lint, interprocedural flow analyses, "
                    "plan-integrity verifier, and rate-stability prover")
    ap.add_argument("command", nargs="?", default="lint",
                    choices=_SUBCOMMANDS, help="analysis to run")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files/directories to analyze (default: src/)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as versioned JSON")
    ap.add_argument("--sarif", metavar="PATH",
                    help="also write findings as SARIF 2.1.0 to PATH")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the full rule catalog and exit")
    ap.add_argument("--include-suppressed", action="store_true",
                    help="report findings even when suppressed")
    ap.add_argument("--verify-smoke", action="store_true",
                    help="build paper fixtures and run all verifier passes")
    ap.add_argument("--simulate", action="store_true",
                    help="prove: cross-check decided cells against the "
                         "co-simulation")
    ap.add_argument("--budget-slots", type=int, default=12,
                    help="prove: fleet slot budget (default 12)")
    ap.add_argument("--max-rate", type=float, default=300.0,
                    help="prove: offered-load ceiling t/s (default 300)")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    raw = list(sys.argv[1:] if argv is None else argv)
    # back-compat: `python -m repro.analysis src/` (path first, no
    # subcommand) still means lint
    if raw and not raw[0].startswith("-") and raw[0] not in _SUBCOMMANDS:
        raw.insert(0, "lint")
    args = _build_parser().parse_args(raw)

    if args.list_rules:
        return list_rules()

    if args.verify_smoke:
        violations = verify_smoke()
        _print(violations, args.json)
        if args.sarif:
            from repro.analysis.sarif import write_sarif
            write_sarif(args.sarif, violations)
        errors = [v for v in violations if v.severity is Severity.ERROR]
        if errors:
            print(f"verify-smoke: {len(errors)} error(s)", file=sys.stderr)
            return 1
        print(f"verify-smoke: clean ({len(violations)} warning(s))"
              if violations else "verify-smoke: clean")
        return 0

    if args.command == "prove":
        if args.paths:
            print("prove: takes no paths (it proves the paper-fixture "
                  "fleet); see --budget-slots/--max-rate", file=sys.stderr)
            return 2
        return run_prove(args)

    paths = args.paths or ["src/"]
    if args.command == "flow":
        from repro.analysis.flow import analyze_paths
        findings = analyze_paths(
            paths, include_suppressed=args.include_suppressed)
        return _finish(findings, "flow", args.json, args.sarif)

    findings = lint_paths(paths, include_suppressed=args.include_suppressed)
    return _finish(findings, "lint", args.json, args.sarif)


if __name__ == "__main__":
    sys.exit(main())
