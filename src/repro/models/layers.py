"""Model building blocks: norms, RoPE, GQA attention, MLPs, embeddings.

All functions are pure; parameter dicts use fixed key names so sharding rules
(repro.distributed.sharding) can pattern-match on paths.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Env, dense_init, embed_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    # zero-init scale with a (1 + scale) gain
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional bias) — init / full (train & prefill) / decode
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, qkv_bias: bool) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(kq, (d_model, num_heads * head_dim)),
        "wk": dense_init(kk, (d_model, num_kv_heads * head_dim)),
        "wv": dense_init(kv, (d_model, num_kv_heads * head_dim)),
        "wo": dense_init(ko, (num_heads * head_dim, d_model)),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,))
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,))
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,))
    return p


def _mha(env: Env, q: jax.Array, k: jax.Array, v: jax.Array, *,
         causal: bool, q_offset: Optional[jax.Array] = None,
         kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference attention.  q: (B,Sq,H,hd), k/v: (B,Sk,K,hd) with H = G*K.

    ``q_offset``: (B,) absolute position of q[:,0] (causal masking in decode /
    chunked prefill).  ``kv_len``: (B,) valid KV length (continuous batching).
    Dispatches to the Pallas flash kernel when env.use_pallas (TPU target) —
    see repro.kernels.flash_attention.  With ``env.attn_q_chunk`` the query
    axis is processed in chunks via lax.scan (flash-style: the live S^2
    score tensor shrinks by the chunk factor; exact, not approximate).
    """
    if env.use_pallas and causal and q.shape[1] > 1:
        from ..kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, q_offset=q_offset,
                               interpret=env.interpret)
    cq = env.attn_q_chunk
    if cq and q.shape[1] > cq and q.shape[1] % cq == 0:
        B, Sq, H, hd = q.shape
        nb = Sq // cq
        base = q_offset if q_offset is not None else jnp.zeros((B,), jnp.int32)

        def block(carry, inp):
            i, qb = inp
            out = _mha_dense(env, qb, k, v, causal=causal,
                             q_offset=base + i * cq, kv_len=kv_len)
            return carry, out

        # remat each chunk: backward recomputes one chunk's S^2 scores at a
        # time instead of saving all of them
        block = jax.checkpoint(block, policy=env.checkpoint_policy())
        qs = jnp.moveaxis(q.reshape(B, nb, cq, H, hd), 1, 0)   # (nb,B,cq,H,hd)
        _, outs = jax.lax.scan(block, None,
                               (jnp.arange(nb, dtype=jnp.int32), qs))
        return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)
    return _mha_dense(env, q, k, v, causal=causal, q_offset=q_offset,
                      kv_len=kv_len)


def _mha_dense(env: Env, q: jax.Array, k: jax.Array, v: jax.Array, *,
               causal: bool, q_offset: Optional[jax.Array] = None,
               kv_len: Optional[jax.Array] = None) -> jax.Array:
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = hd ** -0.5
    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, K, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf)          # (B,K,G,Sq,Sk)
    Sk = k.shape[1]

    def _constrain(t):
        """Pin the S^2 attention internals to one consistent layout —
        query-seq sharded over tp when possible, else key-seq (decode) —
        so SPMD doesn't flip-flop (involuntary full rematerialization)."""
        if env.mesh is None or env.tp_axis is None:
            return t
        b = env.batch_spec_entry()
        if Sq % env.tp == 0 and Sq > 1:
            return env.shard(t, b, None, None, env.tp_axis, None)
        if Sk % env.tp == 0:
            return env.shard(t, b, None, None, None, env.tp_axis)
        return t

    logits = _constrain(logits)
    q_pos = jnp.arange(Sq)[None, :]                            # (1,Sq)
    if q_offset is not None:
        q_pos = q_pos + q_offset[:, None]
    k_pos = jnp.arange(Sk)[None, :]                            # (1,Sk)
    mask = jnp.ones((q_pos.shape[0], Sq, Sk), dtype=bool)
    if causal:
        mask &= q_pos[:, :, None] >= k_pos[:, None, :]
    if kv_len is not None:
        mask &= k_pos[:, None, :] < kv_len[:, None, None]
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = _constrain(jax.nn.softmax(logits, axis=-1))
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vf)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_block(env: Env, p: Params, x: jax.Array, *, num_heads: int,
                    num_kv_heads: int, head_dim: int, rope_theta: float,
                    positions: jax.Array, causal: bool = True,
                    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                    kv_len: Optional[jax.Array] = None,
                    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                    use_rope: bool = True,
                    ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """One attention sublayer (no norm/residual).

    Modes:
    * train/prefill: kv_cache None -> full self-attention; returns fresh
      (k, v) so prefill can populate a cache.
    * decode: kv_cache=(k_cache, v_cache) of shape (B, S_max, K, hd); the
      single new (k, v) is written at ``positions`` and attention runs over
      the cache with ``kv_len`` masking.
    * cross-attention: ``cross_kv`` precomputed from the encoder.
    """
    B, Sq, D = x.shape
    H, K, hd = num_heads, num_kv_heads, head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, Sq, H, hd)

    if cross_kv is not None:
        k, v = cross_kv
        if use_rope:
            q = apply_rope(q, positions, rope_theta)
        out = _mha(env, q, k, v, causal=False, kv_len=kv_len)
        out = out.reshape(B, Sq, H * hd)
        return jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype)), None

    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if "bk" in p:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    k = k.reshape(B, Sq, K, hd)
    v = v.reshape(B, Sq, K, hd)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if env.tp_axis:
        q = env.shard(q, env.batch_spec_entry(), None,
                      env.tp_entry_if_divisible(H), None)

    if kv_cache is None:
        out = _mha(env, q, k, v, causal=causal,
                   q_offset=positions[:, 0] if causal else None)
        new_cache = (k, v)
    else:
        k_cache, v_cache = kv_cache
        b_idx = jnp.arange(B)
        # write the new token's K/V at its position (per-sequence)
        pos = positions[:, 0]
        k_cache = k_cache.at[b_idx, pos].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[b_idx, pos].set(v[:, 0].astype(v_cache.dtype))
        lens = kv_len if kv_len is not None else pos + 1
        out = _mha(env, q, k_cache, v_cache, causal=False, kv_len=lens)
        new_cache = (k_cache, v_cache)
    out = out.reshape(B, Sq, H * hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int) -> Params:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": dense_init(kg, (d_model, d_ff)),
        "wu": dense_init(ku, (d_model, d_ff)),
        "wd": dense_init(kd, (d_ff, d_model)),
    }


def swiglu(env: Env, p: Params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(x.dtype))
    if env.tp_axis:
        f_entry = env.tp_entry_if_divisible(g.shape[-1])
        g = env.shard(g, env.batch_spec_entry(), None, f_entry)
        u = env.shard(u, env.batch_spec_entry(), None, f_entry)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(x.dtype))


def init_gelu_mlp(key, d_model: int, d_ff: int) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "w1": dense_init(k1, (d_model, d_ff)),
        "b1": jnp.zeros((d_ff,)),
        "w2": dense_init(k2, (d_ff, d_model)),
        "b2": jnp.zeros((d_model,)),
    }


def gelu_mlp(env: Env, p: Params, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w1"].astype(x.dtype)) + p["b1"].astype(x.dtype)
    if env.tp_axis:
        h = env.shard(h, env.batch_spec_entry(), None,
                      env.tp_entry_if_divisible(h.shape[-1]))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(x.dtype)) + p["b2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int) -> jax.Array:
    return embed_init(key, (vocab, d_model))


def embed(env: Env, table: jax.Array, tokens: jax.Array,
          dtype=None) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    return out.astype(dtype or env.compute_dtype)


def lm_head(env: Env, table_or_w: jax.Array, x: jax.Array,
            *, transpose: bool) -> jax.Array:
    """Logits; with tied embeddings pass the embedding table and
    transpose=True."""
    w = table_or_w.astype(x.dtype)
    logits = (jnp.einsum("bsd,vd->bsv", x, w) if transpose
              else jnp.einsum("bsd,dv->bsv", x, w))
    if env.tp_axis:
        logits = env.shard(logits, env.batch_spec_entry(), None,
                           env.tp_entry_if_divisible(logits.shape[-1]))
    return logits
