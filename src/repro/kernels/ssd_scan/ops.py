"""Jit-ready SSD scan op (model layout) with reference-recompute backward."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_fwd
from .ref import ssd_reference


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int,
             init_state: Optional[jax.Array] = None,
             interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (Bt,S,H,P)  dt: (Bt,S,H)  A: (H,)  B/C: (Bt,S,N).

    Pallas forward; backward recomputes through the pure-jnp reference
    (same trade as the flash op: fwd kernel is the hot path, bwd pays one
    reference fwd to avoid persisting per-chunk internals).
    """
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], x.shape[2], x.shape[3],
                                B.shape[-1]), jnp.float32)

    @jax.custom_vjp
    def _ssd(x, dt, A, B, C, init_state):
        return ssd_scan_fwd(x, dt, A, B, C, chunk=chunk,
                            init_state=init_state, interpret=interpret)

    def _fwd(x, dt, A, B, C, init_state):
        return _ssd(x, dt, A, B, C, init_state), (x, dt, A, B, C, init_state)

    def _bwd(res, g):
        x, dt, A, B, C, init_state = res
        _, vjp = jax.vjp(
            lambda x, dt, A, B, C, ini: ssd_reference(
                x, dt, A, B, C, chunk=chunk, init_state=ini),
            x, dt, A, B, C, init_state)
        return vjp(g)

    _ssd.defvjp(_fwd, _bwd)
    return _ssd(x, dt, A, B, C, init_state)
