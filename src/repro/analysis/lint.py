"""JAX-hazard and race-hazard codebase lint (stdlib ``ast`` only).

Walks Python sources flagging the two hazard families this repo has been
bitten by:

* **JAX recompile hazards** — patterns that defeat ``jax.jit``'s compile
  cache or silently bake Python values into traced code (the bug class
  PR 4's structural-signature kernel cache fixed);
* **race hazards** — shared mutable state reachable from concurrent
  callers without a lock.

Every rule is a :class:`Rule` whose docstring carries a *bad/good* pair
(mirrored in ``docs/INVARIANTS.md``).  Findings reuse the
:class:`~repro.core.diagnostics.Violation` model with ``artifact`` = file
path and ``path`` = ``file:line``.

Suppression
-----------
A finding is suppressed by a trailing (or immediately preceding) comment
on its line naming the rule (or a comma-separated list of rules, or the
``*`` wildcard for every rule) with a reason::

    self._ops[key] = jax.jit(fn)   # lint: ok JAX101 - one-time init cache
    y = jax.jit(f)(x)              # lint: ok JAX101,JAX102 - one-shot tool
    z = risky()                    # lint: ok * - exhaustively reviewed

The reason text is required convention (the lint only checks the marker,
reviewers check the reason).  A suppression naming a code that no rule
owns (see :data:`KNOWN_CODES` — the lint rules plus the
:mod:`repro.analysis.flow` interprocedural families) is reported as a
``LINT001`` WARNING instead of being silently ignored: dead suppressions
usually mean a typo that leaves the real finding live.  ``lint_paths``
reports unsuppressed findings only; the CLI exits non-zero when any
ERROR-severity finding remains.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.diagnostics import Severity, Violation

_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*ok\s+(\*|[A-Z]+\d+(?:\s*,\s*[A-Z]+\d+)*)")

#: Codes a suppression comment may legitimately name: the body-local lint
#: rules below plus the interprocedural families of
#: :mod:`repro.analysis.flow` (lock-order RACE21x, cross-function JAX11x).
#: ``repro.analysis.flow`` asserts its analyzer codes stay a subset.
KNOWN_CODES: Set[str] = {
    "JAX101", "JAX102", "JAX103", "JAX104", "RACE201", "RACE202",
    # repro.analysis.locks / repro.analysis.jaxflow (interprocedural)
    "RACE210", "RACE211", "RACE212", "JAX110", "JAX111", "JAX112",
}

#: Mutating method names on dict/list/set that count as writes.
_MUTATORS = {"append", "add", "update", "pop", "popitem", "setdefault",
             "clear", "extend", "remove", "insert", "discard"}


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    check: Callable[["_Module"], List[Tuple[int, str]]]
    doc: str


class _Module:
    """Parsed module plus the source-level context rules need."""

    def __init__(self, filename: str, source: str) -> None:
        self.filename = filename
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=filename)
        # ast.walk with parent links for loop-containment questions
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        # suppression map: line -> codes named there ("*" = everything)
        self.suppress: Dict[int, Set[str]] = {}
        self.unknown_suppressions: List[Tuple[int, str]] = []
        for ln, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            codes = {c.strip() for c in m.group(1).split(",")}
            self.suppress[ln] = codes
            for c in sorted(codes):
                if c != "*" and c not in KNOWN_CODES:
                    self.unknown_suppressions.append((ln, c))

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        while node in self.parents:
            node = self.parents[node]
            yield node

    def suppressed(self, line: int, code: str) -> bool:
        for ln in (line, line - 1):
            codes = self.suppress.get(ln)
            if codes and (code in codes or "*" in codes):
                return True
        return False


def _is_jax_attr(node: ast.AST, names: Sequence[str]) -> bool:
    """True for ``jax.<name>`` attribute accesses with ``name`` in names."""
    return (isinstance(node, ast.Attribute) and node.attr in names
            and isinstance(node.value, ast.Name) and node.value.id == "jax")


def _mentions_jnp(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Name) and n.id == "jnp"
               for n in ast.walk(node))


# ---------------------------------------------------------------------------
# JAX recompile hazards.
# ---------------------------------------------------------------------------

def _jax101(mod: _Module) -> List[Tuple[int, str]]:
    """JAX101 — jit/vmap/pmap constructed inside a loop body.

    Every ``jax.jit(f)`` call returns a FRESH callable with its own compile
    cache; constructing one per loop iteration recompiles per iteration.

    bad::

        for x in batches:
            y = jax.jit(step)(x)        # retraces every iteration

    good::

        step_c = jax.jit(step)          # once, outside the loop
        for x in batches:
            y = step_c(x)

    Building a *persistent* cache in a one-time setup loop is legitimate —
    suppress with a reason (see ``runtime/executor.py``)."""
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and \
                _is_jax_attr(node.func, ("jit", "vmap", "pmap")):
            for anc in mod.ancestors(node):
                if isinstance(anc, (ast.For, ast.While, ast.AsyncFor)):
                    # the loop's own iterable/test is evaluated once
                    out.append((node.lineno,
                                f"jax.{node.func.attr} constructed inside a "
                                f"loop (line {anc.lineno}): a fresh callable "
                                "per iteration defeats the compile cache"))
                    break
                if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Lambda)):
                    break   # a nested def inside a loop runs once per call
    return out


def _jax102(mod: _Module) -> List[Tuple[int, str]]:
    """JAX102 — inline ``jax.jit(f)(args)``: construct-and-call.

    The jitted wrapper is thrown away after one call, so its compile cache
    dies with it — every execution retraces.

    bad::

        result = jax.jit(loss_fn)(params, batch)

    good::

        loss_c = jax.jit(loss_fn)       # kept; cache lives across calls
        result = loss_c(params, batch)

    (``jax.vmap`` has no compile cache of its own, so inline vmap under an
    enclosing jit is fine and not flagged.)"""
    out = []
    for node in ast.walk(mod.tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Call)
                and _is_jax_attr(node.func.func, ("jit",))):
            out.append((node.lineno,
                        "jax.jit(f)(...) constructs and discards the jitted "
                        "callable per call — hoist the jit"))
    return out


def _jax103(mod: _Module) -> List[Tuple[int, str]]:
    """JAX103 — Python branch on a traced value.

    ``if``/``while`` force a concrete bool; inside jit that raises a
    TracerBoolConversionError, outside it silently bakes one execution's
    data into control flow.

    bad::

        if jnp.any(queues > 0):         # concretizes a traced array
            drain()

    good::

        jax.lax.cond(jnp.any(queues > 0), drain, skip, state)
    """
    out = []
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.If, ast.While)) and _mentions_jnp(node.test):
            out.append((node.test.lineno,
                        "Python if/while on a jnp expression branches on a "
                        "traced value — use lax.cond/lax.while_loop or "
                        "np.* on concrete data"))
    return out


def _jax104(mod: _Module) -> List[Tuple[int, str]]:
    """JAX104 — numpy closure constant baked into a jit-returned kernel.

    A factory that builds an ``np.*`` array and returns ``jax.jit(inner)``
    bakes that array into the traced graph as a CONSTANT: two factory
    calls with different arrays are two different compiled programs even
    when shapes match — the exact recompile class PR 4's scan-kernel cache
    fixed by keying kernels on structure and passing placement data as
    operands.

    bad::

        def make_kernel(placement):
            frac = np.asarray(placement)     # data, not structure
            def kernel(x):
                return x * jnp.asarray(frac)  # baked constant -> retrace
            return jax.jit(kernel)

    good::

        def make_kernel():
            def kernel(x, frac):              # operand: traced, shared
                return x * frac
            return jax.jit(kernel)

    Arrays that are part of the factory's cache key (structural constants)
    are legitimate — suppress with a reason (see
    ``core/simulator.py::_make_scan_kernel``)."""
    out = []
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # locals assigned from np.<...>(...) in this function's own body
        np_locals: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                f = node.value.func
                root = f
                while isinstance(root, ast.Attribute):
                    root = root.value
                if isinstance(root, ast.Name) and root.id == "np":
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            np_locals.add(tgt.id)
        if not np_locals:
            continue
        # nested defs handed to jax.jit(...) anywhere inside this function
        jitted: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and _is_jax_attr(node.func,
                                                           ("jit",)):
                if node.args and isinstance(node.args[0], ast.Name):
                    jitted.add(node.args[0].id)
        if not jitted:
            continue
        inners = [n for n in ast.walk(fn)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and n is not fn and n.name in jitted]
        for inner in inners:
            params = {a.arg for a in inner.args.args + inner.args.kwonlyargs}
            for node in ast.walk(inner):
                if (isinstance(node, ast.Name) and node.id in np_locals
                        and node.id not in params
                        and isinstance(node.ctx, ast.Load)):
                    out.append((node.lineno,
                                f"np-built closure {node.id!r} read inside "
                                f"jitted {inner.name!r}: baked as a compile-"
                                "time constant — pass it as an operand or "
                                "key the factory's cache on it"))
    return out


# ---------------------------------------------------------------------------
# Race hazards.
# ---------------------------------------------------------------------------

def _module_level_mutables(mod: _Module) -> Set[str]:
    muts: Set[str] = set()
    for node in mod.tree.body:
        if isinstance(node, ast.Assign):
            v = node.value
            mutable = isinstance(v, (ast.Dict, ast.List, ast.Set)) or (
                isinstance(v, ast.Call) and isinstance(v.func, ast.Name)
                and v.func.id in ("dict", "list", "set", "defaultdict",
                                  "OrderedDict", "Counter", "deque"))
            if mutable:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        muts.add(tgt.id)
    return muts


def _module_level_locks(mod: _Module) -> Set[str]:
    locks: Set[str] = set()
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            f = node.value.func
            if (isinstance(f, ast.Attribute) and f.attr in ("Lock", "RLock")
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "threading"):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        locks.add(tgt.id)
    return locks


def _race201(mod: _Module) -> List[Tuple[int, str]]:
    """RACE201 — module-level mutable cache mutated without a lock.

    Module globals are shared by every thread; get-then-set on them races
    (lost updates, torn stats).  The repo's fixed exemplar is
    ``core/simulator.py::get_scan_kernel``: its compiled-kernel cache and
    hit/miss counters (``_KERNEL_CACHE``/``_KERNEL_STATS``) are now
    mutated only under the module-level ``_KERNEL_LOCK``.

    bad::

        _CACHE = {}
        def get(key):
            if key not in _CACHE:        # check-then-act race
                _CACHE[key] = build(key)
            return _CACHE[key]

    good::

        _CACHE = {}
        _LOCK = threading.Lock()
        def get(key):
            with _LOCK:
                if key not in _CACHE:
                    _CACHE[key] = build(key)
                return _CACHE[key]
    """
    muts = _module_level_mutables(mod)
    locks = _module_level_locks(mod)
    if not muts:
        return []
    out = []
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        shadowed = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
        for node in ast.walk(fn):
            name: Optional[str] = None
            what = ""
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
                targets = (node.targets if isinstance(node, (ast.Assign,
                                                             ast.Delete))
                           else [node.target])
                for tgt in targets:
                    if (isinstance(tgt, ast.Subscript)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id in muts
                            and tgt.value.id not in shadowed):
                        name, what = tgt.value.id, "subscript write"
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Attribute)
                  and node.func.attr in _MUTATORS
                  and isinstance(node.func.value, ast.Name)
                  and node.func.value.id in muts
                  and node.func.value.id not in shadowed):
                name, what = node.func.value.id, f".{node.func.attr}()"
            if name is None:
                continue
            held = any(
                isinstance(anc, ast.With) and any(
                    isinstance(item.context_expr, ast.Name)
                    and item.context_expr.id in locks
                    for item in anc.items)
                for anc in mod.ancestors(node))
            if not held:
                out.append((node.lineno,
                            f"module-level mutable {name!r} mutated "
                            f"({what}) outside a module-level "
                            "threading.Lock"))
    return out


def _race202(mod: _Module) -> List[Tuple[int, str]]:
    """RACE202 — mutable default argument.

    A ``def f(x, acc=[])`` default is ONE object shared by every call (and
    every thread) for the life of the process — classic cross-call state
    leak that reads like a local.

    bad::

        def collect(x, acc=[]):
            acc.append(x)
            return acc

    good::

        def collect(x, acc=None):
            acc = [] if acc is None else acc
            acc.append(x)
            return acc
    """
    out = []
    for fn in ast.walk(mod.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
            continue
        for default in list(fn.args.defaults) + \
                [d for d in fn.args.kw_defaults if d is not None]:
            bad = isinstance(default, (ast.Dict, ast.List, ast.Set)) or (
                isinstance(default, ast.Call)
                and isinstance(default.func, ast.Name)
                and default.func.id in ("dict", "list", "set", "defaultdict"))
            if bad:
                label = getattr(fn, "name", "<lambda>")
                out.append((default.lineno,
                            f"mutable default argument in {label!r} is "
                            "shared across all calls — default to None"))
    return out


RULES: List[Rule] = [
    Rule("JAX101", "jit-in-loop", _jax101, _jax101.__doc__ or ""),
    Rule("JAX102", "inline-jit-call", _jax102, _jax102.__doc__ or ""),
    Rule("JAX103", "traced-branch", _jax103, _jax103.__doc__ or ""),
    Rule("JAX104", "baked-closure-constant", _jax104, _jax104.__doc__ or ""),
    Rule("RACE201", "unlocked-module-cache", _race201, _race201.__doc__ or ""),
    Rule("RACE202", "mutable-default-arg", _race202, _race202.__doc__ or ""),
]


def lint_source(source: str, filename: str = "<string>",
                *, include_suppressed: bool = False) -> List[Violation]:
    """Lint one source text; returns unsuppressed findings (all rules)."""
    try:
        mod = _Module(filename, source)
    except SyntaxError as err:
        return [Violation("LINT000", Severity.ERROR, filename,
                          f"{filename}:{err.lineno or 0}",
                          f"syntax error: {err.msg}")]
    out: List[Violation] = []
    for rule in RULES:
        for line, detail in rule.check(mod):
            if include_suppressed or not mod.suppressed(line, rule.code):
                out.append(Violation(rule.code, Severity.ERROR, filename,
                                     f"{filename}:{line}", detail))
    for line, code in mod.unknown_suppressions:
        out.append(Violation("LINT001", Severity.WARNING, filename,
                             f"{filename}:{line}",
                             f"suppression names unknown code {code!r} — "
                             "typo? the finding it meant to silence (if any) "
                             "is still reported"))
    return sorted(out, key=lambda v: (v.artifact, v.path, v.code))


def iter_py_files(paths: Sequence[str]) -> List[str]:
    """Every ``.py`` under ``paths``.  Walks skip ``fixtures`` subtrees —
    those hold deliberately-buggy exemplars (``tests/fixtures/flow``) that
    must not fail a whole-tree lint; point at the directory or file
    explicitly to analyze them."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git",
                                              "fixtures"))
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
    return files


def lint_paths(paths: Sequence[str],
               *, include_suppressed: bool = False) -> List[Violation]:
    """Lint every ``.py`` under ``paths`` (files or directories)."""
    out: List[Violation] = []
    for f in iter_py_files(paths):
        with open(f, "r", encoding="utf-8") as fh:
            out.extend(lint_source(fh.read(), f,
                                   include_suppressed=include_suppressed))
    return out
