"""Vectorized batch planning engine vs the scalar reference paths.

The contract of repro.core.batch / the sweep simulator is *equivalence*: the
array passes must reproduce the scalar allocators, the +10 t/s planning scan,
and per-rate simulator runs — while doing asymptotically less work.
"""

import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:        # property tests skip; plain tests still run
    from _hypothesis_fallback import hypothesis, st

from repro.core import (ALL_DAGS, MICRO_DAGS, DataflowSimulator,
                        UnsupportableRateError, batch_allocate,
                        batch_feasible, batch_slots, allocate_lsa,
                        allocate_mba, linear_dag, paper_library, plan)
from repro.core.batch import bisect_largest_true, prefix_feasible_count
from repro.core.perfmodel import PAPER_MODELS
from repro.core.scheduler import max_planned_rate

PAIRS = (("lsa", "dsm"), ("lsa", "rsm"),
         ("mba", "dsm"), ("mba", "rsm"), ("mba", "sam"))


@pytest.fixture(scope="module")
def lib():
    return paper_library()


# -- vectorized PerfModel accessors ------------------------------------------

def test_perfmodel_array_matches_scalar():
    """Array-valued I/C/M are bit-identical to scalar evaluation."""
    rng = np.random.default_rng(0)
    for kind, m in PAPER_MODELS.items():
        qs = np.concatenate([rng.uniform(-2, m.points[-1].tau + 5, 200),
                             np.arange(0, m.points[-1].tau + 3, dtype=float)])
        for fn in (m.I, m.C, m.M):
            vec = fn(qs)
            assert vec.shape == qs.shape
            assert np.array_equal(vec, np.array([fn(float(q)) for q in qs]))


def test_perfmodel_inverse_matches_scalar():
    """T_many equals the scalar smallest-adequate-thread-count search."""
    rng = np.random.default_rng(1)
    for kind, m in PAPER_MODELS.items():
        omegas = np.concatenate([rng.uniform(0, m.omega_hat * 1.3, 200),
                                 [0.0, -5.0, m.omega_hat]])
        got = m.T_many(omegas)
        for w, t in zip(omegas, got):
            ref = m.T(float(w))
            assert t == (-1 if ref is None else ref)


# -- batch allocation vs scalar allocators -----------------------------------

@pytest.mark.parametrize("algo,scalar", [("lsa", allocate_lsa),
                                         ("mba", allocate_mba)])
def test_batch_allocate_matches_scalar(lib, algo, scalar):
    omegas = np.arange(10.0, 510.0, 10.0)
    for name, mk in ALL_DAGS.items():
        dag = mk()
        ba = batch_allocate(dag, omegas, lib, algo)
        for k in range(0, len(omegas), 7):
            ref = scalar(dag, float(omegas[k]), lib)
            assert ba.slots[k] == ref.slots
            for i, tname in enumerate(ba.task_names):
                t = ref.tasks[tname]
                assert ba.threads[i, k] == t.threads, (name, tname)
                assert ba.cpu[i, k] == pytest.approx(t.cpu, abs=1e-9)
                assert ba.mem[i, k] == pytest.approx(t.mem, abs=1e-9)


def test_batch_feasible_fleet(lib):
    """Fleet call: per-DAG feasibility masks over one shared rate grid."""
    omegas = np.arange(10.0, 310.0, 10.0)
    dags = {name: mk() for name, mk in MICRO_DAGS.items()}
    masks = batch_feasible(dags, omegas, lib, algorithm="mba",
                           budget_slots=20)
    assert set(masks) == set(dags)
    for name, mask in masks.items():
        ref = batch_slots(dags[name], omegas, lib, "mba") <= 20
        assert np.array_equal(mask, ref)
        assert mask[0]        # 10 t/s fits 20 slots on every micro DAG


@hypothesis.given(omega=st.floats(min_value=1.0, max_value=800.0))
@hypothesis.settings(max_examples=30, deadline=None)
def test_batch_slots_property(omega):
    """Any single rate evaluated through the batch path equals the scalar
    allocator's slot estimate."""
    lib = paper_library()
    dag = linear_dag()
    assert batch_slots(dag, [omega], lib, "mba")[0] == \
        allocate_mba(dag, omega, lib).slots


# -- bisection planner vs the §8.5 linear scan --------------------------------

def test_bisect_matches_scan_all_dags(lib):
    """Identical planned rate on every seed (DAG, scheduler pair), with >=5x
    fewer scalar allocator calls in aggregate (the §8.5 protocol cost)."""
    scan_calls = bisect_calls = 0
    for name, mk in ALL_DAGS.items():
        for alloc_name, map_name in PAIRS:
            dag = mk()
            s_scan, s_bis = {}, {}
            r_scan = max_planned_rate(dag, lib, allocator=alloc_name,
                                      mapper=map_name, budget_slots=20,
                                      method="scan", stats=s_scan)
            r_bis = max_planned_rate(dag, lib, allocator=alloc_name,
                                     mapper=map_name, budget_slots=20,
                                     method="bisect", stats=s_bis)
            assert r_scan == r_bis, (name, alloc_name, map_name)
            scan_calls += s_scan["allocator_calls"]
            bisect_calls += s_bis["allocator_calls"]
    assert bisect_calls * 5 <= scan_calls, (scan_calls, bisect_calls)


def test_bisect_zero_when_nothing_fits(lib):
    """The widest app DAG cannot run on a single slot at any grid rate."""
    from repro.core import grid_dag
    for method in ("scan", "bisect"):
        assert max_planned_rate(grid_dag(), lib, allocator="mba",
                                mapper="sam", budget_slots=1,
                                method=method) == 0.0


# -- unsupportable rates through the batch path --------------------------------

def test_batch_unsupportable_raises_typed_error():
    """The vectorized MBA/LSA inner loops raise the scalar allocators' typed
    error, not a bare AssertionError."""
    from test_allocation import dead_task_setup
    dag, models = dead_task_setup()
    for algo in ("lsa", "mba"):
        with pytest.raises(UnsupportableRateError) as exc:
            batch_allocate(dag, [10.0, 20.0], models, algo)
        # same metadata as the scalar path: task name + full task rate
        assert exc.value.task == "d"
        assert exc.value.rate == pytest.approx(10.0)


def test_batch_clip_unsupportable_marks_infeasible():
    """clip_unsupportable turns unsupportable cells into never-fitting slot
    counts instead of aborting the whole grid pass."""
    from test_allocation import dead_task_setup
    dag, models = dead_task_setup()
    slots = batch_slots(dag, [10.0, 20.0], models, "mba",
                        clip_unsupportable=True)
    assert (slots > 10**15).all()          # no finite budget fits


def test_batch_feasible_clips_degenerate_dag_by_default():
    """One degenerate DAG must not abort the whole fleet's masks — it just
    reads as infeasible at every rate."""
    from test_allocation import dead_task_setup
    dag, models = dead_task_setup()
    masks = batch_feasible({"dead": dag}, [10.0, 20.0], models,
                           budget_slots=10 ** 6)
    assert not masks["dead"].any()


def test_near_degenerate_profile_clamps_instead_of_wrapping():
    """A tiny-but-positive peak rate demands astronomically many threads and
    slots; the int64 casts must clamp, not wrap negative (a wrapped slot
    count would read as feasible under ANY budget)."""
    from repro.core import ModelLibrary, PerfModel
    from repro.core.perfmodel import PAPER_MODELS
    from repro.core.dag import Dataflow

    models = ModelLibrary({
        "tiny": PerfModel.from_points("tiny", {1: (1e-19, 0.5, 0.5)}),
        "source": PAPER_MODELS["source"], "sink": PAPER_MODELS["sink"]})
    df = Dataflow("tinyflow")
    df.add_task("src", "source", is_source=True)
    df.add_task("t", "tiny")
    df.add_task("snk", "sink", is_sink=True)
    df.add_edge("src", "t")
    df.add_edge("t", "snk")
    for algo, scalar in (("lsa", allocate_lsa), ("mba", allocate_mba)):
        ba = batch_allocate(df, [10.0], models, algo)
        assert (ba.threads >= 0).all()
        assert (ba.slots > 10 ** 15).all()
        masks = batch_feasible({"tiny": df}, [10.0], models,
                               budget_slots=10 ** 6, algorithm=algo)
        assert not masks["tiny"].any()
        # the scalar allocators terminate on the same profile (floor
        # arithmetic — repeated subtraction of 1e-19 would never end) and
        # agree the rate needs an absurd slot count
        ref = scalar(df, 10.0, models)
        assert ref.slots > 10 ** 15
    for method in ("scan", "bisect"):
        assert max_planned_rate(df, models, allocator="mba", mapper="sam",
                                budget_slots=20, method=method) == 0.0


def test_scan_and_bisect_agree_on_unsupportable_rates(lib):
    """Satellite acceptance: both max_planned_rate methods report 0.0 when
    no grid rate is allocatable, instead of crashing (scan) or aborting the
    vectorized pass (bisect)."""
    from test_allocation import dead_task_setup
    dag, models = dead_task_setup()
    rates = {m: max_planned_rate(dag, models, allocator="mba", mapper="sam",
                                 budget_slots=20, method=m)
             for m in ("scan", "bisect")}
    assert rates["scan"] == rates["bisect"] == 0.0


# -- bisection / prefix-count edge cases ---------------------------------------

def test_bisect_largest_true_edge_cases():
    def pred_of(mask):
        return lambda i: mask[i]

    assert bisect_largest_true(pred_of([]), 0) == -1            # empty grid
    assert bisect_largest_true(pred_of([False] * 5), 5) == -1   # all False
    assert bisect_largest_true(pred_of([True]), 1) == 0         # single True
    assert bisect_largest_true(pred_of([False]), 1) == -1
    assert bisect_largest_true(pred_of([True] * 7), 7) == 6     # all True
    for n_true in range(1, 7):
        mask = [True] * n_true + [False] * (7 - n_true)
        assert bisect_largest_true(pred_of(mask), 7) == n_true - 1


def test_bisect_largest_true_lo_known_true_skips_first_probe():
    """lo_known_true trusts the caller: index 0 is never probed, and with an
    (invariant-violating) all-False predicate the search still terminates,
    answering 0."""
    probed = []

    def pred(i):
        probed.append(i)
        return False

    assert bisect_largest_true(pred, 8, lo_known_true=True) == 0
    assert 0 not in probed


def test_prefix_feasible_count_masks():
    assert prefix_feasible_count(np.array([], dtype=bool)) == 0
    assert prefix_feasible_count(np.ones(9, dtype=bool)) == 9
    assert prefix_feasible_count(np.zeros(9, dtype=bool)) == 0
    # stops at the FIRST infeasible rate even if later ones fit again
    assert prefix_feasible_count(np.array([True, False, True])) == 1
    assert prefix_feasible_count(np.array([True, True, False, False])) == 2


# -- sweep simulator vs per-rate runs -----------------------------------------

def test_simulate_sweep_matches_per_rate_runs(lib):
    dag = linear_dag()
    s = plan(dag, 100, lib, allocator="mba", mapper="sam")
    sim = DataflowSimulator(dag, s.allocation, s.mapping, lib)
    omegas = np.linspace(20.0, 140.0, 13)
    swept = sim.simulate_sweep(omegas, duration=10, dt=0.1)
    for w, r in zip(omegas, swept):
        ref = sim.run(float(w), duration=10, dt=0.1)
        assert r.stable == ref.stable
        assert r.latency_slope == pytest.approx(ref.latency_slope, abs=1e-12)
        assert r.mean_latency == pytest.approx(ref.mean_latency, abs=1e-12)
        assert r.p99_latency == pytest.approx(ref.p99_latency, abs=1e-12)
        assert r.queue_total == pytest.approx(ref.queue_total, abs=1e-9)
        assert r.slot_busy.keys() == ref.slot_busy.keys()
        for slot, busy in ref.slot_busy.items():
            assert r.slot_busy[slot] == pytest.approx(busy, abs=1e-12)


def test_sweep_finds_stability_boundary(lib):
    """Stability along the sweep is monotone and brackets the predicted
    capacity of the schedule."""
    dag = linear_dag()
    s = plan(dag, 100, lib, allocator="mba", mapper="sam")
    sim = DataflowSimulator(dag, s.allocation, s.mapping, lib)
    omegas = np.linspace(20.0, 200.0, 19)
    stable = [r.stable for r in sim.simulate_sweep(omegas, duration=10, dt=0.1)]
    assert stable[0] and not stable[-1]
    assert stable == sorted(stable, reverse=True)  # True...True False...False


def test_max_stable_rate_consistent_with_sweep(lib):
    dag = linear_dag()
    s = plan(dag, 100, lib, allocator="mba", mapper="sam")
    sim = DataflowSimulator(dag, s.allocation, s.mapping, lib)
    r = sim.max_stable_rate(duration=10, dt=0.1)
    lo, hi = sim.simulate_sweep([r * 0.95, r * 1.1], duration=10, dt=0.1)
    assert lo.stable
    assert not hi.stable
