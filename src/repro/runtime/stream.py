"""Micro-batch stream framing for the executor, over a pluggable clock.

The executor's pacing, latency accounting, retry backoff, watchdog and
load-shedding decisions all read ONE clock object.  :class:`WallClock` is
the real thing; :class:`VirtualClock` advances only when slept on, which
makes whole chaos replays deterministic (bit-identical timelines across
runs) and fast (no real sleeping) — the mode the chaos test-suite and
``benchmarks/bench_chaos.py`` run in.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class WallClock:
    """Real time: ``perf_counter`` + ``time.sleep``."""

    virtual = False

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class VirtualClock:
    """Deterministic simulated time: ``sleep`` advances, nothing blocks."""

    virtual = True

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def now(self) -> float:
        return self.t

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            self.t += float(seconds)


@dataclasses.dataclass
class MicroBatch:
    """A frame of tuples moving through the dataflow."""

    seq: int                      # frame sequence number
    arrays: Dict[str, jax.Array]  # leading axis = tuple axis
    created: float                # clock arrival time at the source (s)

    @property
    def size(self) -> int:
        return next(iter(self.arrays.values())).shape[0]


class SyntheticSource:
    """Constant-rate synthetic tuple source (§8.3: single opaque field).

    Emits micro-batches of ``batch`` tuples; the admission times honour the
    requested rate *on the supplied clock* so end-to-end latency
    measurements are meaningful under both wall and virtual time.
    """

    def __init__(self, rate: float, batch: int = 32, payload_len: int = 256,
                 seed: int = 0, clock: Optional[WallClock] = None,
                 start_seq: int = 0):
        self.rate = rate
        self.batch = batch
        self.payload_len = payload_len
        self.rng = np.random.default_rng(seed)
        self.clock = clock if clock is not None else WallClock()
        self._seq = int(start_seq)

    def frames(self, duration: float = 0.0, *,
               n_frames: Optional[int] = None) -> Iterator[MicroBatch]:
        if n_frames is None:
            n_frames = max(1, int(self.rate * duration / self.batch))
        interval = self.batch / self.rate
        start = self.clock.now()
        for i in range(n_frames):
            sched = start + i * interval
            now = self.clock.now()
            if sched > now:
                self.clock.sleep(sched - now)
            payload = self.rng.integers(32, 127, size=(self.batch, self.payload_len),
                                        dtype=np.uint8)
            value = self.rng.random(self.batch, dtype=np.float32)
            yield MicroBatch(
                seq=self._seq,
                arrays={"payload": jnp.asarray(payload), "value": jnp.asarray(value)},
                created=max(sched, self.clock.now()),
            )
            self._seq += 1
