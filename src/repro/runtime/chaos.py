"""Deterministic, seed-replayable fault injection for the live runtime.

The robustness claim of the reproduction — the planner's model-driven
schedules survive the failures a real cluster throws at them — is only
falsifiable if the failures themselves are *reproducible*.  This module
provides that: a :class:`FaultPlan` is a pure-data description of every
fault a replay will see, keyed exclusively on deterministic coordinates
(DAG name, frame sequence number, task name, VM index), never on wall
clock.  Two replays of the same plan therefore produce bit-identical
fault timelines (``tests/test_chaos.py`` pins this).

Fault taxonomy (the RIoTBench / event-storm failure modes):

``OPERATOR_ERROR``   the operator body raises for ``count`` consecutive
                     attempts at a (frame, task) coordinate — transient
                     for small counts (the retry path absorbs it),
                     persistent for large ones (the circuit breaker
                     escalates).
``SLOT_SLOWDOWN``    every part processed by the targeted task/VM runs
                     ``factor``× slower for ``frames`` frames (CPU
                     contention, noisy neighbours).
``SLOT_STALL``       one processing attempt blocks for ``seconds`` —
                     long enough to trip the frame-timeout watchdog.
``DROP_FRAME``       the frame is lost between routing and the operator
                     (network drop); counted as shed load.
``VM_CRASH``         every operator on the VM fails persistently from
                     ``frame`` onward — repair requires the controller
                     to replace the VM (``VmFail``).  Correlated storms
                     are several VM_CRASH faults sharing one frame.

The :class:`FaultInjector` is the per-executor active view: the executor
consults it between routing and ``_run_task`` and every injected fault is
appended to the injector's :class:`FaultTimeline`.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np


class FaultKind(enum.Enum):
    OPERATOR_ERROR = "operator_error"
    SLOT_SLOWDOWN = "slot_slowdown"
    SLOT_STALL = "slot_stall"
    DROP_FRAME = "drop_frame"
    VM_CRASH = "vm_crash"

    def __str__(self) -> str:  # pragma: no cover - repr aid
        return self.value


class InjectedOperatorError(RuntimeError):
    """The exception an OPERATOR_ERROR / VM_CRASH fault raises in place of
    the operator body."""

    def __init__(self, kind: FaultKind, task: str, detail: str = ""):
        super().__init__(f"injected {kind.value} at task {task!r}"
                         + (f": {detail}" if detail else ""))
        self.fault_kind = kind
        self.task = task


@dataclasses.dataclass(frozen=True)
class Fault:
    """One planned fault, addressed by deterministic coordinates.

    ``dag=None`` matches every DAG; ``task=None`` matches every task;
    VM targeting is by ``vm_index`` — the position in the schedule's VM
    list at injection time — because absolute VM ids are minted by the
    controller and unknown when a plan is authored.
    """

    kind: FaultKind
    frame: int                       # first frame seq the fault applies to
    dag: Optional[str] = None
    task: Optional[str] = None
    vm_index: Optional[int] = None
    frames: int = 1                  # duration in frames (slowdown / drop)
    count: int = 1                   # consecutive failing attempts (errors)
    factor: float = 2.0              # slowdown multiplier
    seconds: float = 0.0             # stall duration

    def matches_dag(self, dag: str) -> bool:
        return self.dag is None or self.dag == dag

    def active(self, frame: int) -> bool:
        return self.frame <= frame < self.frame + self.frames


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One realized injection — the unit of the replayable timeline."""

    frame: int
    dag: str
    kind: FaultKind
    task: str        # "" for frame/VM-scoped faults
    target: str      # slot / vm / frame coordinate, stringified
    detail: str


@dataclasses.dataclass
class FaultTimeline:
    """Ordered record of every injected fault of one replay."""

    events: List[FaultEvent] = dataclasses.field(default_factory=list)

    def add(self, ev: FaultEvent) -> None:
        self.events.append(ev)

    def signature(self) -> Tuple[Tuple, ...]:
        """Hashable bit-exact identity of the timeline (determinism pin)."""
        return tuple(
            (e.frame, e.dag, e.kind.value, e.task, e.target, e.detail)
            for e in self.events)

    def __len__(self) -> int:
        return len(self.events)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A pure-data, time-free fault schedule for a whole fleet replay."""

    faults: Tuple[Fault, ...] = ()
    seed: Optional[int] = None

    @classmethod
    def none(cls) -> "FaultPlan":
        """The empty plan — the fault-free no-op rail."""
        return cls(faults=(), seed=None)

    @classmethod
    def from_seed(cls, seed: int, *, dags: Sequence[str], tasks: Sequence[str],
                  horizon_frames: int = 24, operator_errors: int = 2,
                  slowdowns: int = 2, stalls: int = 0, drops: int = 1,
                  vm_crashes: int = 0, correlated_crash: bool = False,
                  crash_frame: Optional[int] = None) -> "FaultPlan":
        """Generate a bursty fault mix deterministically from ``seed``.

        Every coordinate is drawn from one ``np.random.default_rng(seed)``
        stream in a fixed order, so the same arguments always produce the
        same plan — and two replays of that plan produce bit-identical
        timelines.  ``correlated_crash`` adds an event-storm-style
        correlated failure (two VM_CRASH faults sharing one frame).
        """
        rng = np.random.default_rng(seed)
        dags = list(dags)
        tasks = list(tasks)
        faults: List[Fault] = []
        for _ in range(operator_errors):
            faults.append(Fault(
                FaultKind.OPERATOR_ERROR,
                frame=int(rng.integers(1, horizon_frames)),
                dag=dags[int(rng.integers(len(dags)))],
                task=tasks[int(rng.integers(len(tasks)))],
                count=int(rng.integers(1, 3))))
        for _ in range(slowdowns):
            faults.append(Fault(
                FaultKind.SLOT_SLOWDOWN,
                frame=int(rng.integers(1, horizon_frames)),
                dag=dags[int(rng.integers(len(dags)))],
                task=tasks[int(rng.integers(len(tasks)))],
                frames=int(rng.integers(2, 5)),
                factor=float(np.round(1.5 + 2.5 * rng.random(), 3))))
        for _ in range(stalls):
            faults.append(Fault(
                FaultKind.SLOT_STALL,
                frame=int(rng.integers(1, horizon_frames)),
                dag=dags[int(rng.integers(len(dags)))],
                task=tasks[int(rng.integers(len(tasks)))],
                seconds=float(np.round(0.5 + rng.random(), 3))))
        for _ in range(drops):
            faults.append(Fault(
                FaultKind.DROP_FRAME,
                frame=int(rng.integers(1, horizon_frames)),
                dag=dags[int(rng.integers(len(dags)))]))
        for _ in range(vm_crashes):
            faults.append(Fault(
                FaultKind.VM_CRASH,
                frame=int(rng.integers(1, horizon_frames)),
                dag=dags[int(rng.integers(len(dags)))],
                vm_index=int(rng.integers(0, 2))))
        if correlated_crash:
            cf = (int(rng.integers(2, max(3, horizon_frames // 2)))
                  if crash_frame is None else int(crash_frame))
            victim = dags[int(rng.integers(len(dags)))]
            faults.append(Fault(FaultKind.VM_CRASH, frame=cf, dag=victim,
                                vm_index=0))
            faults.append(Fault(FaultKind.VM_CRASH, frame=cf, dag=victim,
                                vm_index=1))
        faults.sort(key=lambda f: (f.frame, f.kind.value, f.dag or "",
                                   f.task or "", f.vm_index or -1))
        return cls(faults=tuple(faults), seed=seed)

    def for_dag(self, dag: str) -> Tuple[Fault, ...]:
        return tuple(f for f in self.faults if f.matches_dag(dag))

    def __len__(self) -> int:
        return len(self.faults)


class FaultInjector:
    """Per-executor active view of one DAG's slice of a :class:`FaultPlan`.

    The executor consults it between routing and ``_run_task``:

    * :meth:`drop_frame` — frame-scoped drops;
    * :meth:`error_attempts` — how many consecutive attempts at
      (frame, task, slot) must fail (decremented per retry by the caller
      via the returned budget);
    * :meth:`slowdown` / :meth:`stall` — extra processing cost;
    * :meth:`crashed` — VM-scoped persistent failure (until the VM id is
      replaced by repair; replacements carry fresh ids, so they are
      healthy by construction).

    Every consultation that fires appends to :attr:`timeline`.
    """

    def __init__(self, plan: FaultPlan, dag: str,
                 timeline: Optional[FaultTimeline] = None):
        self.plan = plan
        self.dag = dag
        self.faults = plan.for_dag(dag)
        self.timeline = timeline if timeline is not None else FaultTimeline()
        #: VM ids realized as crashed (resolved from vm_index at first
        #: injection against the executor's VM list)
        self._crashed_ids: Set[int] = set()
        self._crash_logged: Set[Tuple[int, int]] = set()
        #: VM_CRASH faults (by index into ``self.faults``) already realized
        #: — a crash fires once, against the VM list of the frame it hits;
        #: repair replacements carry fresh ids and stay healthy
        self._fired_crashes: Set[int] = set()

    # -- frame-scoped --------------------------------------------------------
    def drop_frame(self, frame: int) -> bool:
        for f in self.faults:
            if f.kind is FaultKind.DROP_FRAME and f.active(frame):
                self._log(frame, f.kind, "", f"frame#{frame}",
                          "frame dropped between routing and operators")
                return True
        return False

    # -- VM-scoped -----------------------------------------------------------
    def crashed_vms(self, frame: int, vm_ids: Sequence[int]) -> Set[int]:
        """Resolve VM_CRASH faults active at ``frame`` against the
        executor's current VM id list; crashed ids persist until repair
        replaces them (fresh ids never match)."""
        vm_ids = list(vm_ids)
        for i, f in enumerate(self.faults):
            if (f.kind is not FaultKind.VM_CRASH or frame < f.frame
                    or i in self._fired_crashes):
                continue
            self._fired_crashes.add(i)
            if f.vm_index is None or f.vm_index >= len(vm_ids):
                continue
            vid = vm_ids[f.vm_index]
            if vid in self._crashed_ids:
                continue
            self._crashed_ids.add(vid)
            self._log(frame, f.kind, "", f"vm{vid}",
                      f"VM crash (vm_index={f.vm_index})")
        return {v for v in self._crashed_ids if v in vm_ids}

    def is_crashed(self, vm_id: int) -> bool:
        return vm_id in self._crashed_ids

    # -- task/slot-scoped ----------------------------------------------------
    def error_attempts(self, frame: int, task: str, slot) -> int:
        """Consecutive attempts that must fail at this coordinate (0 =
        healthy).  VM crashes dominate: every attempt on a crashed VM
        fails."""
        if slot.vm in self._crashed_ids:
            key = (frame, slot.vm)
            if key not in self._crash_logged:
                self._crash_logged.add(key)
                self._log(frame, FaultKind.VM_CRASH, task, repr(slot),
                          f"attempt on crashed vm{slot.vm}")
            return 1 << 30
        n = 0
        for f in self.faults:
            if (f.kind is FaultKind.OPERATOR_ERROR and f.active(frame)
                    and (f.task is None or f.task == task)):
                n = max(n, f.count)
        if n:
            self._log(frame, FaultKind.OPERATOR_ERROR, task, repr(slot),
                      f"{n} failing attempt(s)")
        return n

    def slowdown(self, frame: int, task: str, slot) -> float:
        factor = 1.0
        for f in self.faults:
            if (f.kind is FaultKind.SLOT_SLOWDOWN and f.active(frame)
                    and (f.task is None or f.task == task)):
                factor *= f.factor
        if factor != 1.0:
            self._log(frame, FaultKind.SLOT_SLOWDOWN, task, repr(slot),
                      f"factor={factor:g}")
        return factor

    def stall(self, frame: int, task: str, slot) -> float:
        secs = 0.0
        for f in self.faults:
            if (f.kind is FaultKind.SLOT_STALL and f.active(frame)
                    and (f.task is None or f.task == task)):
                secs += f.seconds
        if secs:
            self._log(frame, FaultKind.SLOT_STALL, task, repr(slot),
                      f"stall={secs:g}s")
        return secs

    # -- internals -----------------------------------------------------------
    def _log(self, frame: int, kind: FaultKind, task: str, target: str,
             detail: str) -> None:
        self.timeline.add(FaultEvent(frame=frame, dag=self.dag, kind=kind,
                                     task=task, target=target, detail=detail))


#: A null injector usable where "no faults" must still satisfy the
#: injector interface.
def null_injector(dag: str = "") -> FaultInjector:
    return FaultInjector(FaultPlan.none(), dag)
