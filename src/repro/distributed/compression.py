"""Gradient compression: int8 error-feedback all-reduce.

For DP gradient sync on bandwidth-constrained links (the multi-pod "pod"
axis rides DCN, ~25x slower than ICI): quantize grads to int8 with a
per-block scale before the cross-pod reduction and keep the quantization
residual locally (error feedback), adding it back into the next step's
grads — the standard EF-SGD construction that preserves convergence.

Usage inside a shard_map DP region:

    comp = ErrorFeedbackCompressor(block=256)
    grads, state = comp.reduce(grads, state, axis_name="pod")

Under pure pjit auto-parallelism XLA owns the reduction, so this is an
opt-in path for shard_map-based launchers (see launch/train.py docs).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


def _quant(x: jax.Array, block: int) -> Tuple[jax.Array, jax.Array]:
    """Symmetric signed int8 per-block quantization along the last axis."""
    last = x.shape[-1]
    pad = (-last) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    nb = (last + pad) // block
    blocks = x.reshape(*x.shape[:-1], nb, block)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def _dequant(q: jax.Array, scale: jax.Array, orig_last: int,
             block: int) -> jax.Array:
    blocks = q.astype(jnp.float32) * scale[..., None]
    flat = blocks.reshape(*q.shape[:-2], q.shape[-2] * block)
    return flat[..., :orig_last]


@dataclasses.dataclass(frozen=True)
class ErrorFeedbackCompressor:
    block: int = 256

    def init_state(self, grads: Params) -> Params:
        """Residual accumulator, same shapes as grads (fp32)."""
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress(self, grads: Params, residual: Params
                 ) -> Tuple[Params, Params, Params]:
        """(quantized, scales, new_residual): residual holds what int8
        couldn't represent and is re-added next step."""
        def one(g, r):
            x = g.astype(jnp.float32) + r
            q, s = _quant(x, self.block)
            deq = _dequant(q, s, x.shape[-1], self.block)
            return q, s, x - deq
        triples = jax.tree.map(one, grads, residual)
        is3 = lambda t: isinstance(t, tuple) and len(t) == 3
        qs = jax.tree.map(lambda t: t[0], triples, is_leaf=is3)
        ss = jax.tree.map(lambda t: t[1], triples, is_leaf=is3)
        rs = jax.tree.map(lambda t: t[2], triples, is_leaf=is3)
        return qs, ss, rs

    def reduce(self, grads: Params, residual: Params, axis_name: str
               ) -> Tuple[Params, Params]:
        """Error-feedback compressed psum over ``axis_name`` (int8 on the
        wire: 4x fewer bytes than fp32, 2x fewer than bf16)."""
        qs, ss, new_residual = self.compress(grads, residual)
        n = jax.lax.psum(1, axis_name)

        def one(g, q, s):
            # sum int8 payloads in int32 (lossless across <=2^23 peers),
            # scales reduced separately; mean across the axis
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            smean = jax.lax.pmean(s, axis_name)
            deq = _dequant(qsum, smean, g.shape[-1], self.block)
            return (deq / n).astype(g.dtype)
        reduced = jax.tree.map(one, grads, qs, ss)
        return reduced, new_residual
