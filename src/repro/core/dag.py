"""Streaming dataflow DAG (paper §3).

A :class:`Dataflow` is a DAG ``G = (T, E)`` whose vertices are tasks and whose
edges carry tuple streams with a *selectivity* ``sigma_ij`` (output tuples per
input tuple on that edge).  The input-rate recurrence of §6::

    omega_j = Omega                                  if t_j is a source
    omega_j = sum_{e_ij} omega_i * sigma_ij * f_ij   otherwise

where ``f_ij`` is the routing fraction of the edge (1.0 for *duplicate*
semantics — every out-edge carries the full output stream — and ``1/k`` for
*split* semantics over ``k`` out-edges, used by the Star micro-DAG hub so the
spokes see the DAG rate, per Fig. 5).
"""

from __future__ import annotations

import dataclasses
import enum
from collections import defaultdict, deque
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple


class Routing(enum.Enum):
    """Semantics of a task's *outgoing* edge set (§2)."""

    DUPLICATE = "duplicate"  # every out-edge carries the full output rate
    SPLIT = "split"          # output rate divided equally over out-edges


@dataclasses.dataclass(frozen=True)
class Task:
    """A dataflow vertex.

    ``kind`` keys into the performance-model library (several vertices may
    share a kind, e.g. two `pi` tasks in the Finance DAG).  ``name`` is unique
    within a Dataflow.
    """

    name: str
    kind: str
    is_source: bool = False
    is_sink: bool = False


@dataclasses.dataclass(frozen=True)
class Edge:
    src: str
    dst: str
    selectivity: float = 1.0


class Dataflow:
    """A streaming dataflow DAG with selectivity-weighted edges."""

    def __init__(self, name: str):
        self.name = name
        self.tasks: Dict[str, Task] = {}
        self.edges: List[Edge] = []
        self.routing: Dict[str, Routing] = {}

    # -- construction ------------------------------------------------------
    def add_task(self, name: str, kind: str, *, is_source: bool = False,
                 is_sink: bool = False, routing: Routing = Routing.DUPLICATE) -> Task:
        if name in self.tasks:
            raise ValueError(f"duplicate task {name!r}")
        t = Task(name, kind, is_source, is_sink)
        self.tasks[name] = t
        self.routing[name] = routing
        return t

    def add_edge(self, src: str, dst: str, selectivity: float = 1.0) -> Edge:
        for endpoint in (src, dst):
            if endpoint not in self.tasks:
                raise KeyError(f"unknown task {endpoint!r}")
        e = Edge(src, dst, selectivity)
        self.edges.append(e)
        return e

    # -- structure ---------------------------------------------------------
    def out_edges(self, name: str) -> List[Edge]:
        return [e for e in self.edges if e.src == name]

    def in_edges(self, name: str) -> List[Edge]:
        return [e for e in self.edges if e.dst == name]

    def sources(self) -> List[Task]:
        have_in = {e.dst for e in self.edges}
        return [t for t in self.tasks.values() if t.name not in have_in]

    def sinks(self) -> List[Task]:
        have_out = {e.src for e in self.edges}
        return [t for t in self.tasks.values() if t.name not in have_out]

    def topo_order(self) -> List[Task]:
        """Kahn topological order (deterministic: insertion order tiebreak)."""
        indeg = {n: 0 for n in self.tasks}
        for e in self.edges:
            indeg[e.dst] += 1
        order: List[Task] = []
        ready = deque(n for n in self.tasks if indeg[n] == 0)
        while ready:
            n = ready.popleft()
            order.append(self.tasks[n])
            for e in self.out_edges(n):
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    ready.append(e.dst)
        if len(order) != len(self.tasks):
            raise ValueError(f"dataflow {self.name!r} has a cycle")
        return order

    def logic_tasks(self) -> List[Task]:
        """Tasks that are neither source nor sink (the schedulable user logic
        plus source/sink are all scheduled; this helper is for reporting)."""
        return [t for t in self.topo_order() if not (t.is_source or t.is_sink)]

    # -- rates (GetRate, §6) -------------------------------------------------
    def get_rates(self, omega: float) -> Dict[str, float]:
        """Input rate per task for DAG input rate ``omega`` (recurrence of §6),
        evaluated in topological order."""
        rates: Dict[str, float] = {}
        for t in self.topo_order():
            ins = self.in_edges(t.name)
            if not ins:
                rates[t.name] = float(omega)
            else:
                total = 0.0
                for e in ins:
                    src_out = rates[e.src] * e.selectivity
                    if self.routing[e.src] is Routing.SPLIT:
                        src_out /= max(1, len(self.out_edges(e.src)))
                    total += src_out
                rates[t.name] = total
        return rates

    def get_rate(self, task: str, omega: float) -> float:
        return self.get_rates(omega)[task]

    def critical_path_len(self) -> int:
        """Number of tasks on the longest source→sink path (latency proxy,
        §8.6: Diamond 4 < Star 5 < Linear 7)."""
        depth = {n: 1 for n in self.tasks}
        for t in self.topo_order():
            for e in self.out_edges(t.name):
                depth[e.dst] = max(depth[e.dst], depth[t.name] + 1)
        return max(depth.values())

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Dataflow({self.name!r}, tasks={len(self.tasks)}, "
                f"edges={len(self.edges)})")


# ---------------------------------------------------------------------------
# The paper's evaluation dataflows (§8.3, Figs. 5 & 6).
#
# The five representative task kinds (Table 1): X=ParseXML, P=Pi,
# F=BatchFileWrite, B=AzureBlobDownload, T=AzureTableQuery.
# All selectivities are 1:1 (§8.3).  Each DAG gets an explicit source and
# sink task (kinds "source"/"sink", §8.3 static allocation).
# ---------------------------------------------------------------------------

def _with_endpoints(df: Dataflow, heads: Sequence[str], tails: Sequence[str]) -> Dataflow:
    df.add_task("src", "source", is_source=True)
    df.add_task("snk", "sink", is_sink=True)
    for h in heads:
        df.add_edge("src", h)
    for t in tails:
        df.add_edge(t, "snk")
    return df


def linear_dag() -> Dataflow:
    """Fig. 5 Linear: sequential flow; every task sees the DAG rate."""
    df = Dataflow("linear")
    order = [("x", "parse_xml"), ("p", "pi"), ("f", "batch_file_write"),
             ("b", "azure_blob"), ("t", "azure_table")]
    for n, k in order:
        df.add_task(n, k)
    for (a, _), (b, _) in zip(order, order[1:]):
        df.add_edge(a, b)
    return _with_endpoints(df, heads=["x"], tails=["t"])


def diamond_dag() -> Dataflow:
    """Fig. 5 Diamond: fan-out then fan-in (task parallelism).

    The head *splits* its output across the three middle branches so each
    branch sees the DAG input rate / 3 ... per Fig. 5's annotations all tasks
    see the DAG rate; the paper draws the fan-out with duplicate semantics
    and a fan-in that interleaves, but reports each middle task at the DAG
    rate, so the head uses SPLIT routing here.  The tail (fan-in) then sees
    the full DAG rate again.
    """
    df = Dataflow("diamond")
    df.add_task("x", "parse_xml", routing=Routing.SPLIT)
    df.add_task("p", "pi")
    df.add_task("b", "azure_blob")
    df.add_task("t", "azure_table")
    df.add_task("f", "batch_file_write")
    for mid in ("p", "b", "t"):
        df.add_edge("x", mid)
        df.add_edge(mid, "f")
    # With SPLIT at the head each branch carries Omega/3 and the fan-in sums
    # back to Omega.
    return _with_endpoints(df, heads=["x"], tails=["f"])


def star_dag() -> Dataflow:
    """Fig. 5 Star: hub-and-spoke; the hub sees 2x the DAG rate (two in-edges
    at the DAG rate), and its out-edges SPLIT so the two egress spokes see the
    DAG rate each."""
    df = Dataflow("star")
    df.add_task("b", "azure_blob")
    df.add_task("f", "batch_file_write")
    df.add_task("x", "parse_xml", routing=Routing.SPLIT)  # hub
    df.add_task("p", "pi")
    df.add_task("t", "azure_table")
    df.add_edge("b", "x")
    df.add_edge("f", "x")
    df.add_edge("x", "p")
    df.add_edge("x", "t")
    return _with_endpoints(df, heads=["b", "f"], tails=["p", "t"])


def traffic_dag() -> Dataflow:
    """Fig. 6 Traffic (GPS stream analytics, ~7 logic tasks): parse, then a
    fan-out to speed analytics / archival, with DB + cloud lookups."""
    df = Dataflow("traffic")
    df.add_task("parse", "parse_xml")
    df.add_task("filter", "pi")            # map-matching / filtering analytics
    df.add_task("speed", "pi")             # average-speed analytics
    df.add_task("archive", "batch_file_write")
    df.add_task("lookup", "azure_table")
    df.add_task("model", "azure_blob")     # fetch road model
    df.add_task("agg", "batch_file_write")
    df.add_edge("parse", "filter")
    df.add_edge("parse", "archive")
    df.add_edge("filter", "speed")
    df.add_edge("filter", "lookup")
    df.add_edge("speed", "model")
    df.add_edge("lookup", "agg")
    df.add_edge("model", "agg")
    return _with_endpoints(df, heads=["parse"], tails=["agg", "archive"])


def finance_dag() -> Dataflow:
    """Fig. 6 Finance (bargain-index over stock trades, ~8 logic tasks),
    FP-heavy: parse, dedup, moving average, bargain index, persistence."""
    df = Dataflow("finance")
    df.add_task("parse", "parse_xml")
    df.add_task("dedup", "pi")
    df.add_task("vwap", "pi")              # volume-weighted average price
    df.add_task("mavg", "pi")              # moving average
    df.add_task("bargain", "pi")           # bargain index
    df.add_task("hist", "azure_table")     # historic quotes
    df.add_task("store", "batch_file_write")
    df.add_task("alert", "batch_file_write")
    df.add_edge("parse", "dedup")
    df.add_edge("dedup", "vwap")
    df.add_edge("dedup", "mavg")
    df.add_edge("vwap", "bargain")
    df.add_edge("mavg", "bargain")
    df.add_edge("bargain", "hist")
    df.add_edge("hist", "alert")
    df.add_edge("bargain", "store")
    return _with_endpoints(df, heads=["parse"], tails=["alert", "store"])


def grid_dag() -> Dataflow:
    """Fig. 6 Grid (smart-meter pre-processing + predictive analytics,
    ~15 logic tasks): parsing, DB ops, time-series analytics; the widest DAG
    with the highest fan-out (overall selectivity up to 1:4)."""
    df = Dataflow("grid")
    df.add_task("parse", "parse_xml")
    df.add_task("clean", "pi")
    df.add_task("meta", "azure_table")
    df.add_task("join", "pi")
    df.add_task("archive", "batch_file_write")
    df.add_task("interp", "pi")            # interpolation of gaps
    df.add_task("weather", "azure_blob")   # weather model download
    df.add_task("trend", "pi")             # time-series trend
    df.add_task("forecast", "pi")          # demand forecast
    df.add_task("baseline", "azure_table")
    df.add_task("compare", "pi")
    df.add_task("detect", "pi")            # anomaly detect
    df.add_task("notify", "batch_file_write")
    df.add_task("store", "azure_table")
    df.add_task("report", "batch_file_write")
    df.add_edge("parse", "clean")
    df.add_edge("parse", "archive")
    df.add_edge("clean", "meta")
    df.add_edge("clean", "interp")
    df.add_edge("meta", "join")
    df.add_edge("interp", "join")
    df.add_edge("join", "weather")
    df.add_edge("join", "trend")
    df.add_edge("weather", "forecast")
    df.add_edge("trend", "forecast")
    df.add_edge("forecast", "baseline")
    df.add_edge("baseline", "compare")
    df.add_edge("compare", "detect")
    df.add_edge("detect", "notify")
    df.add_edge("compare", "store")
    df.add_edge("detect", "report")
    return _with_endpoints(df, heads=["parse"], tails=["notify", "store", "report", "archive"])


MICRO_DAGS: Dict[str, Callable[[], Dataflow]] = {
    "linear": linear_dag,
    "diamond": diamond_dag,
    "star": star_dag,
}

APP_DAGS: Dict[str, Callable[[], Dataflow]] = {
    "traffic": traffic_dag,
    "finance": finance_dag,
    "grid": grid_dag,
}

ALL_DAGS: Dict[str, Callable[[], Dataflow]] = {**MICRO_DAGS, **APP_DAGS}
