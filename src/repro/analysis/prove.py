"""Static rate-stability prover (interval arithmetic over the §6 recurrence).

Decides — *without running the simulator* — whether an allocation/schedule
sustains a DAG input rate, by propagating rate intervals along the DAG
edges and comparing them against per-group capacity bounds:

* task rates follow the paper's §6 recurrence
  ``omega_j = sum_i omega_i * sigma_ij * f_ij`` (SPLIT routing divides by
  the out-edge count) — linear in the input rate, so per task
  ``rate = beta * Omega``; with a selectivity slack ``s`` every edge
  multiplier widens to ``[m(1-s), m(1+s)]`` and the betas become
  intervals;
* a (task, slot) thread group of ``q`` threads serves at most the model's
  ``I_t(q)`` (§8.4.1) and receives ``frac * beta * Omega`` of the task's
  rate (routing fractions are rate-independent);
* the §8.4.2 CPU-oversubscription penalty only ever *shrinks* capacity,
  so a cell is proved stable only when the upper-bound rate-scaled CPU
  of every slot also fits its core — otherwise the penalty could bite
  and the verdict stays unprovable.

Verdicts per (dag, rate) cell:

* ``proved_stable`` — every binding group's demand upper bound fits its
  capacity AND no slot can oversubscribe its core: the (fluid) simulator
  cannot show queue growth.  Sound because the simulator's served rate
  never exceeds demand and its effective capacity never exceeds
  ``I_t(q)``.
* ``proved_unstable`` — some group's demand LOWER bound exceeds its
  capacity by ``unstable_margin`` (RATE301), or a group with positive
  demand has zero capacity (RATE304): queues must grow regardless of
  the penalty (which only shrinks capacity further).
* ``unprovable`` — everything in between: borderline cells (RATE302) or
  cells whose stability hinges on the oversubscription fixed point
  (RATE303).

Planners use proved cells to skip co-simulation
(:meth:`repro.core.online.FleetController.cosimulate` with
``prove=True``); unprovable cells still simulate.  The module needs only
numpy — no jax import — so ``python -m repro.analysis prove`` stays
cheap.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.diagnostics import Severity, Violation
from repro.core.predictor import GroupIndex, build_group_index

PROVED_STABLE = "proved_stable"
PROVED_UNSTABLE = "proved_unstable"
UNPROVABLE = "unprovable"

#: (code, name, one-line summary) — the CLI's ``--list-rules`` and the
#: SARIF rule table draw from this.
RATE_RULES: List[Tuple[str, str, str]] = [
    ("RATE301", "proved-unstable",
     "a group's demand lower bound exceeds its §8.4.1 capacity by the "
     "unstable margin — queues must grow at this rate"),
    ("RATE302", "borderline-cell",
     "demand interval straddles capacity for some group — cell "
     "unprovable, fall back to co-simulation"),
    ("RATE303", "cpu-oversub-unprovable",
     "a slot's upper-bound rate-scaled CPU exceeds its core, so the "
     "§8.4.2 penalty may throttle capacity — cell unprovable"),
    ("RATE304", "zero-capacity-demand",
     "a group with positive demand has zero model capacity — proved "
     "unstable"),
    ("RATE305", "allocation-rate-mismatch",
     "a task's allocated rate falls outside the §6 recurrence interval "
     "for the DAG input rate — the allocation is internally inconsistent"),
    ("RATE309", "prover-simulator-disagreement",
     "a cell the prover decided disagrees with the co-simulation's "
     "verdict (emitted only by `prove --simulate`) — a soundness bug"),
]


@dataclasses.dataclass(frozen=True)
class Interval:
    """A non-negative closed interval ``[lo, hi]``."""

    lo: float
    hi: float

    def __post_init__(self) -> None:
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    def __add__(self, other: "Interval") -> "Interval":
        return Interval(self.lo + other.lo, self.hi + other.hi)

    def __mul__(self, other: "Interval") -> "Interval":
        # all quantities here (rates, selectivities, fractions) are >= 0
        return Interval(self.lo * other.lo, self.hi * other.hi)

    def scale(self, k: float) -> "Interval":
        return Interval(self.lo * k, self.hi * k)

    @staticmethod
    def point(x: float) -> "Interval":
        return Interval(x, x)


def beta_intervals(gi: GroupIndex,
                   selectivity_slack: float = 0.0) -> List[Interval]:
    """Per-task rate-per-unit-input intervals via the §6 recurrence.

    ``gi.in_edges`` already folds selectivity and SPLIT fan-out into one
    multiplier per edge; a slack ``s`` widens each to ``[m(1-s), m(1+s)]``.
    Tasks without in-edges (sources) anchor at the exact ``gi.betas``
    value.  Rows are in topo order, so one forward pass suffices.
    """
    s = float(selectivity_slack)
    out: List[Interval] = []
    for row, edges in enumerate(gi.in_edges):
        if not edges:
            out.append(Interval.point(float(gi.betas[row])))
            continue
        acc = Interval.point(0.0)
        for src, mult in edges:
            lo = mult * max(0.0, 1.0 - s)
            hi = mult * (1.0 + s)
            acc = acc + Interval(out[src].lo * lo, out[src].hi * hi)
        out.append(acc)
    return out


@dataclasses.dataclass
class ProofResult:
    """Verdict for one (dag, rate) cell."""

    name: str
    omega: float
    verdict: str                    # PROVED_STABLE / PROVED_UNSTABLE / ...
    margin: float                   # min over binding groups of
    #                                 capacity/demand_hi - 1 (negative when
    #                                 some demand exceeds capacity)
    binding: str                    # worst group, human-readable
    violations: List[Violation]

    @property
    def proved(self) -> bool:
        return self.verdict in (PROVED_STABLE, PROVED_UNSTABLE)


def prove_group_index(gi: GroupIndex, omega: float, *, name: str = "dag",
                      rate_slack: float = 0.0,
                      selectivity_slack: float = 0.0,
                      unstable_margin: float = 0.05) -> ProofResult:
    """Prove one schedule cell stable/unstable, or report unprovable.

    Mirrors :func:`repro.core.predictor.predict_max_rate_gi`'s binding
    constraint (``g_frac * beta * Omega <= I_t(q)`` per group) with
    interval bounds, plus the §8.4.2 soundness condition on slot CPU.
    """
    cell = f"{name}@{omega:g}"
    betas = beta_intervals(gi, selectivity_slack)
    om = Interval(omega * max(0.0, 1.0 - rate_slack),
                  omega * (1.0 + rate_slack))
    viols: List[Violation] = []
    margin = float("inf")
    binding = "(no binding group)"
    borderline: List[str] = []
    all_within = True
    d_hi = np.zeros(gi.n_groups)
    for g in range(gi.n_groups):
        frac = float(gi.g_frac[g])
        d = betas[int(gi.g_task[g])] * om
        d = d.scale(frac)
        d_hi[g] = d.hi
        cap = float(gi.g_cap[g])
        if d.hi <= 0.0:
            continue                       # group receives no traffic
        label = (f"{gi.tasks[int(gi.g_task[g])]}@"
                 f"{gi.slots[int(gi.g_slot[g])]}")
        if cap <= 0.0:
            viols.append(Violation(
                "RATE304", Severity.ERROR, cell, f"{cell}/{label}",
                f"group {label} has zero model capacity but demand >= "
                f"{d.lo:g} t/s — queues must grow"))
            margin = -1.0
            binding = label
            continue
        m = cap / d.hi - 1.0
        if m < margin:
            margin, binding = m, label
        if d.lo > cap * (1.0 + unstable_margin):
            viols.append(Violation(
                "RATE301", Severity.ERROR, cell, f"{cell}/{label}",
                f"group {label} demand lower bound {d.lo:.4g} t/s exceeds "
                f"capacity {cap:.4g} by more than {unstable_margin:.0%} — "
                "proved unstable (the §8.4.2 penalty only shrinks "
                "capacity further)"))
        elif d.hi > cap * (1.0 + 1e-9):
            all_within = False
            borderline.append(
                f"{label}: demand [{d.lo:.4g}, {d.hi:.4g}] vs cap "
                f"{cap:.4g}")
    if any(v.code in ("RATE301", "RATE304") for v in viols):
        return ProofResult(name, omega, PROVED_UNSTABLE, margin, binding,
                           viols)
    if not all_within:
        viols.append(Violation(
            "RATE302", Severity.WARNING, cell, cell,
            "borderline cell — demand interval straddles capacity for: "
            + "; ".join(borderline)))
        return ProofResult(name, omega, UNPROVABLE, margin, binding, viols)
    # every group fits; stability still needs the §8.4.2 soundness check:
    # upper-bound rate-scaled CPU per slot must fit the core, else the
    # penalty could throttle capacity below demand in the simulator
    n_slots = len(gi.slots)
    if gi.n_groups and n_slots:
        frac_used = np.where(gi.g_cap > 0,
                             np.minimum(1.0, d_hi / np.where(
                                 gi.g_cap > 0, gi.g_cap, 1.0)), 1.0)
        slot_cpu = np.zeros(n_slots)
        np.add.at(slot_cpu, gi.g_slot, gi.g_cpu * frac_used)
        worst = int(np.argmax(slot_cpu))
        if slot_cpu[worst] > 1.0 + 1e-9:
            viols.append(Violation(
                "RATE303", Severity.WARNING, cell,
                f"{cell}/{gi.slots[worst]}",
                f"slot {gi.slots[worst]} upper-bound CPU "
                f"{slot_cpu[worst]:.3f} exceeds its core — the §8.4.2 "
                "oversubscription penalty may bite; cell unprovable"))
            return ProofResult(name, omega, UNPROVABLE, margin, binding,
                               viols)
    return ProofResult(name, omega, PROVED_STABLE, margin, binding, viols)


def prove_allocation(dag: "object", alloc: "object", models: "object", *,
                     rate_slack: float = 0.0,
                     selectivity_slack: float = 0.0,
                     unstable_margin: float = 0.05) -> ProofResult:
    """Mapping-independent proof obligations for an :class:`Allocation`.

    * **RATE305** — a task's recorded ``rate`` falls outside the interval
      the §6 recurrence propagates from ``alloc.omega`` (a corrupted or
      hand-edited allocation: the planner's books don't balance).
    * **RATE301** — a task's demand lower bound exceeds the best rate ANY
      mapping of its ``threads`` could serve (``tau * max_q I(q)/q``,
      the per-thread efficiency peak of §8.4.1): proved unstable before
      a mapper even runs.
    """
    from repro.core.dag import Routing
    name = getattr(dag, "name", "dag")
    omega = float(alloc.omega)
    cell = f"{name}@{omega:g}"
    s = float(selectivity_slack)
    order = [t.name for t in dag.topo_order()]
    row_of = {n: i for i, n in enumerate(order)}
    betas: List[Interval] = []
    for tname in order:
        edges = dag.in_edges(tname)
        if not edges:
            betas.append(Interval.point(1.0))
            continue
        acc = Interval.point(0.0)
        for e in edges:
            mult = e.selectivity
            outs = len(dag.out_edges(e.src))
            if dag.routing[e.src] is Routing.SPLIT and outs:
                mult /= outs
            acc = acc + Interval(
                betas[row_of[e.src]].lo * mult * max(0.0, 1.0 - s),
                betas[row_of[e.src]].hi * mult * (1.0 + s))
        betas.append(acc)
    om = Interval(omega * max(0.0, 1.0 - rate_slack),
                  omega * (1.0 + rate_slack))
    viols: List[Violation] = []
    margin = float("inf")
    binding = "(no binding task)"
    for tname in order:
        ta = alloc.tasks.get(tname)
        if ta is None:
            continue
        expect = betas[row_of[tname]] * om
        tol = 1e-6 * max(1.0, expect.hi)
        if not (expect.lo - tol <= ta.rate <= expect.hi + tol):
            viols.append(Violation(
                "RATE305", Severity.ERROR, cell, f"{cell}/{tname}",
                f"allocation records rate {ta.rate:g} t/s for {tname!r} "
                f"but the §6 recurrence propagates "
                f"[{expect.lo:.6g}, {expect.hi:.6g}] from omega "
                f"{omega:g}"))
        model = models[ta.kind]
        tau = int(ta.threads)
        if tau <= 0 or expect.hi <= 0:
            continue
        per_thread = max((model.I(q) / q for q in range(1, tau + 1)),
                        default=0.0)
        best = tau * per_thread
        m = (best / expect.hi - 1.0) if expect.hi > 0 else float("inf")
        if m < margin:
            margin, binding = m, tname
        if best <= 0 or expect.lo > best * (1.0 + unstable_margin):
            viols.append(Violation(
                "RATE301", Severity.ERROR, cell, f"{cell}/{tname}",
                f"task {tname!r} demand lower bound {expect.lo:.4g} t/s "
                f"exceeds the best any mapping of {tau} threads serves "
                f"({best:.4g} = tau * max_q I(q)/q) — proved unstable"))
    verdict = (PROVED_UNSTABLE
               if any(v.code == "RATE301" for v in viols) else UNPROVABLE)
    return ProofResult(name, omega, verdict, margin, binding, viols)


def _models_for(models: "object", name: str) -> "object":
    """Per-DAG model libraries: a plain mapping of name -> library, or one
    shared library (mirrors ``repro.core.fleet._models_for``)."""
    if isinstance(models, dict) and name in models:
        return models[name]
    return models


def prove_fleet(plan: "object", models: Optional[object] = None, *,
                fractions: Optional[Sequence[float]] = None,
                rate_slack: float = 0.0,
                selectivity_slack: float = 0.0,
                unstable_margin: float = 0.05
                ) -> Dict[str, List[ProofResult]]:
    """Prove every (mapped entry, fraction) cell of a fleet plan.

    The sweep axis defaults to ``simulate_fleet``'s (0.25..1.25, 9 points).
    Entries without a schedule or with zero rate are skipped, matching the
    co-simulation's ``skipped`` list.  Uses each entry's cached
    :class:`GroupIndex` when present; otherwise ``models`` is required to
    build one.
    """
    fracs = (np.linspace(0.25, 1.25, 9) if fractions is None
             else np.asarray(fractions, dtype=float))
    out: Dict[str, List[ProofResult]] = {}
    for e in plan.entries.values():
        if getattr(e, "schedule", None) is None or e.omega <= 0:
            continue
        gi = getattr(e, "group_index", None)
        if gi is None:
            if models is None:
                raise ValueError(
                    f"entry {e.name!r} has no cached GroupIndex; pass "
                    "`models` so prove_fleet can build one")
            gi = build_group_index(e.dag, e.schedule.allocation,
                                   e.schedule.mapping,
                                   _models_for(models, e.name),
                                   plan.policy)
        out[e.name] = [
            prove_group_index(gi, float(f) * e.omega, name=e.name,
                              rate_slack=rate_slack,
                              selectivity_slack=selectivity_slack,
                              unstable_margin=unstable_margin)
            for f in fracs]
    return out
