"""Replay a bursty day through the online elastic fleet controller.

Three tenants share one slot budget.  Over the day their offered load
ramps up, one DAG bursts past what the cluster can grant, a VM dies mid-
morning, and two more tenants arrive — every event handled by ONE
incremental replan over cached slot surfaces (a ``batch_slots`` grid pass
runs only when a DAG first arrives).  After each event the live fleet is
co-simulated in one batched sweep and the ControllerLog timeline prints
planned rates, threads migrated, and replan latency per event.

Run:  PYTHONPATH=src python examples/online_controller.py
"""

from repro.core import (DagArrive, DagDepart, EventTrace, FleetController,
                        RateChange, RoutingPolicy, VmAdd, VmFail,
                        diamond_dag, linear_dag, paper_library, star_dag)


def main() -> None:
    lib = paper_library()
    # slot-aware routing: the §11 policy whose simulated behaviour tracks
    # the plan (shuffle would show the known planned-vs-actual gap)
    ctl = FleetController(lib, budget_slots=24, objective="max_min",
                          mapper="sam", step=10.0, max_rate=1000.0,
                          policy=RoutingPolicy.SLOT_AWARE)

    # the day opens with two tenants; "linear" is demand-capped, "diamond"
    # elastically soaks the leftover budget
    ctl.apply(DagArrive("linear", linear_dag(), max_rate=80.0), at=0.0)
    ctl.apply(DagArrive("diamond", diamond_dag()), at=0.5)

    # linear is demand-capped, so its VMs survive the morning ramp intact
    vm_to_fail = ctl.entry("linear").schedule.vms[0].id
    day = EventTrace([
        (9.0, RateChange("linear", 150.0)),     # morning ramp-up
        (10.5, VmFail(vm_to_fail)),             # a host dies
        (11.0, DagArrive("star", star_dag(), weight=2.0)),   # new tenant
        (12.0, RateChange("linear", 600.0)),    # lunch burst: budget-bound
        (13.0, VmAdd(8)),                       # ops grows the cluster
        (15.0, RateChange("linear", 90.0)),     # burst over
        (17.0, DagArrive("traffic-lite", linear_dag(), max_rate=60.0)),
        (22.0, DagDepart("star")),              # evening wind-down
    ])
    ctl.replay(day, simulate=True, fractions=[0.5, 1.0], duration=6.0,
               dt=0.1, warmup=2.0, engine="numpy")

    print(ctl.log.describe())
    print()
    print(ctl.plan.describe())
    passes = ctl.cache.stats["batch_passes"]
    print(f"\nslot-surface grid passes all day: {passes} "
          f"(one per arrival; every other replan was array probes on "
          "cached surfaces)")


if __name__ == "__main__":
    main()
