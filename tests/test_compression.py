"""int8 error-feedback gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (ErrorFeedbackCompressor, _dequant,
                                           _quant)


def test_quant_dequant_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 300)), jnp.float32)
    q, s = _quant(x, 64)
    deq = _dequant(q, s, 300, 64)
    # per-block max error <= scale/2 = max|block| / 254
    err = jnp.abs(deq - x)
    bound = jnp.max(jnp.abs(x)) / 127.0
    assert float(jnp.max(err)) <= float(bound) + 1e-6


def test_error_feedback_accumulates_lost_mass():
    """The residual carries exactly what quantization dropped: compressing a
    constant gradient repeatedly converges to the true mean update."""
    comp = ErrorFeedbackCompressor(block=32)
    g = {"w": jnp.full((64,), 1e-4) + jnp.linspace(0, 3.0, 64)}
    residual = comp.init_state(g)
    total_sent = jnp.zeros(64)
    for _ in range(20):
        qs, ss, residual = comp.compress(g, residual)
        sent = _dequant(qs["w"], ss["w"], 64, 32)
        total_sent = total_sent + sent
    # mean transmitted gradient -> true gradient (error feedback property)
    np.testing.assert_allclose(np.asarray(total_sent / 20),
                               np.asarray(g["w"]), rtol=0.02, atol=1e-4)


def test_reduce_under_shard_map_single_axis():
    """Compressed psum matches the exact mean within quantization error."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import AxisType, make_mesh, shard_map
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    mesh = make_mesh((1,), ("dp",), axis_types=(AxisType.Auto,))
    comp = ErrorFeedbackCompressor(block=32)
    g = {"w": jnp.linspace(-1, 1, 128)}
    state = comp.init_state(g)

    def body(g, r):
        return comp.reduce(g, r, axis_name="dp")

    out, new_state = shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False)(g, state)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=float(jnp.max(jnp.abs(g["w"]))) / 100)
